//! Criterion-like micro/macro benchmark harness (the offline image has no
//! criterion). Used by every target under `benches/`.
//!
//! Features: warm-up, fixed sample count, median/mean/p95/min, throughput
//! units, and a markdown-table reporter whose output goes to stdout (and is
//! captured into bench_output.txt by the final run).

use std::time::{Duration, Instant};

use crate::plan::Plan;
use crate::util::rng::Rng;
use crate::util::stats;

/// One measured benchmark result.
#[derive(Clone, Debug)]
pub struct Measurement {
    pub name: String,
    pub samples: Vec<f64>, // seconds per iteration (or a raw value)
    pub throughput: Option<(f64, &'static str)>, // items per iter, unit label
    /// True for `record_value` entries: samples are raw metric values,
    /// not durations, and are reported unformatted.
    pub is_value: bool,
}

impl Measurement {
    pub fn median_s(&self) -> f64 {
        stats::percentile(&self.samples, 50.0)
    }

    pub fn mean_s(&self) -> f64 {
        stats::mean(&self.samples)
    }

    pub fn p95_s(&self) -> f64 {
        stats::percentile(&self.samples, 95.0)
    }

    pub fn min_s(&self) -> f64 {
        stats::min_max(&self.samples).0
    }
}

/// Benchmark runner: collects measurements, prints a report on `finish`.
pub struct Bench {
    suite: String,
    warmup: Duration,
    samples: usize,
    min_iters: u64,
    results: Vec<Measurement>,
}

impl Bench {
    pub fn new(suite: &str) -> Self {
        // Honor a quick mode so `cargo bench` stays fast in CI-like runs.
        let quick = std::env::var("BENCH_QUICK").is_ok();
        Bench {
            suite: suite.to_string(),
            warmup: if quick {
                Duration::from_millis(50)
            } else {
                Duration::from_millis(300)
            },
            samples: if quick { 10 } else { 30 },
            min_iters: 1,
            results: Vec::new(),
        }
    }

    pub fn with_samples(mut self, n: usize) -> Self {
        self.samples = n;
        self
    }

    /// Measure `f`, timing one call per sample (for macro benchmarks).
    pub fn bench<F: FnMut()>(&mut self, name: &str, mut f: F) {
        self.bench_with_throughput(name, None, &mut f);
    }

    /// Measure with a throughput annotation (items processed per call).
    pub fn bench_throughput<F: FnMut()>(
        &mut self,
        name: &str,
        items: f64,
        unit: &'static str,
        mut f: F,
    ) {
        self.bench_with_throughput(name, Some((items, unit)), &mut f);
    }

    fn bench_with_throughput(
        &mut self,
        name: &str,
        throughput: Option<(f64, &'static str)>,
        f: &mut dyn FnMut(),
    ) {
        // warm-up: run until the warm-up budget elapses
        let start = Instant::now();
        let mut warm_iters = 0u64;
        while start.elapsed() < self.warmup || warm_iters < self.min_iters {
            f();
            warm_iters += 1;
        }
        // choose an inner-iteration count targeting ~10ms per sample
        let per_call = start.elapsed().as_secs_f64() / warm_iters as f64;
        let inner = ((0.01 / per_call.max(1e-9)) as u64).clamp(1, 1_000_000);

        let mut samples = Vec::with_capacity(self.samples);
        for _ in 0..self.samples {
            let t = Instant::now();
            for _ in 0..inner {
                f();
            }
            samples.push(t.elapsed().as_secs_f64() / inner as f64);
        }
        let m = Measurement {
            name: name.to_string(),
            samples,
            throughput,
            is_value: false,
        };
        eprintln!(
            "  {:<48} median {:>12}  p95 {:>12}{}",
            m.name,
            fmt_time(m.median_s()),
            fmt_time(m.p95_s()),
            m.throughput
                .map(|(items, unit)| format!(
                    "  {:>12.1} {}/s",
                    items / m.median_s(),
                    unit
                ))
                .unwrap_or_default()
        );
        self.results.push(m);
    }

    /// Record an already-computed scalar series (for figure regeneration
    /// benches that report metric values rather than wall time).
    pub fn record_value(&mut self, name: &str, value: f64, unit: &'static str) {
        eprintln!("  {name:<48} value {value:>14.4} {unit}");
        self.results.push(Measurement {
            name: format!("{name} [{unit}]"),
            samples: vec![value],
            throughput: None,
            is_value: true,
        });
    }

    /// Print the final markdown table. Returns results for programmatic use.
    pub fn finish(self) -> Vec<Measurement> {
        println!("\n## bench suite: {}\n", self.suite);
        println!("| benchmark | median | mean | p95 | min | throughput |");
        println!("|---|---|---|---|---|---|");
        for m in &self.results {
            if m.is_value {
                println!(
                    "| {} | {:.4} | - | - | - | - |",
                    m.name, m.samples[0]
                );
                continue;
            }
            let tp = m
                .throughput
                .map(|(items, unit)| {
                    format!("{:.1} {}/s", items / m.median_s(), unit)
                })
                .unwrap_or_else(|| "-".into());
            println!(
                "| {} | {} | {} | {} | {} | {} |",
                m.name,
                fmt_time(m.median_s()),
                fmt_time(m.mean_s()),
                fmt_time(m.p95_s()),
                fmt_time(m.min_s()),
                tp
            );
        }
        println!();
        self.results
    }
}

// --- shared reference paths --------------------------------------------------

/// The pre-arena SLIT neighbour generator: one owned `Plan` clone per
/// candidate, cycling the same four move kinds with the same RNG call
/// sequence as `plan::PlanBatch::push_neighbors_of`. This is the single
/// shared reference path for the arena parity assertions
/// (rust/src/plan.rs unit test, rust/tests/bench_rows.rs) and the
/// arena-vs-clone bench row (benches/hot_path.rs) — one copy, so the
/// reference and the benchmarks cannot drift apart when the move set
/// changes.
pub fn clone_path_neighbors(
    cur: &Plan,
    n: usize,
    step: f64,
    rng: &mut Rng,
) -> Vec<Plan> {
    let mut out = Vec::with_capacity(n);
    for c in 0..n {
        out.push(match c % 4 {
            // directed move toward a random DC
            2 => {
                let k = rng.below(cur.classes);
                let to = rng.below(cur.dcs);
                cur.shifted_toward(k, to, rng.range(0.2, 0.8))
            }
            // snap-to-vertex: collapse one row onto its argmax
            3 => {
                let k = rng.below(cur.classes);
                let best = cur
                    .row(k)
                    .iter()
                    .enumerate()
                    .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                    .map(|(l, _)| l)
                    .unwrap_or(0);
                cur.shifted_toward(k, best, 1.0)
            }
            _ => cur.perturbed(step, rng),
        });
    }
    out
}

// --- allocation-count harness -----------------------------------------------

/// Counting wrapper around the system allocator. Register it in a test or
/// bench binary with
/// `#[global_allocator] static A: CountingAlloc = CountingAlloc;`
/// and measure a closure with [`count_allocs`] — that is how
/// rust/tests/alloc_hotpath.rs pins `AnalyticEvaluator::evaluate`, the
/// delta-scoring core, and the `PlanBatch` candidate build at **zero**
/// heap operations. The counter is thread-local, so pool workers and
/// concurrently running `#[test]` threads never pollute a measurement.
pub struct CountingAlloc;

thread_local! {
    static ALLOC_OPS: std::cell::Cell<u64> = const { std::cell::Cell::new(0) };
}

#[inline]
fn bump_alloc_ops() {
    // Cell<u64> has no destructor, so this TLS access never allocates —
    // safe to run inside the allocator itself.
    ALLOC_OPS.with(|c| c.set(c.get() + 1));
}

unsafe impl std::alloc::GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: std::alloc::Layout) -> *mut u8 {
        bump_alloc_ops();
        std::alloc::System.alloc(layout)
    }

    unsafe fn alloc_zeroed(&self, layout: std::alloc::Layout) -> *mut u8 {
        bump_alloc_ops();
        std::alloc::System.alloc_zeroed(layout)
    }

    unsafe fn realloc(
        &self,
        ptr: *mut u8,
        layout: std::alloc::Layout,
        new_size: usize,
    ) -> *mut u8 {
        bump_alloc_ops();
        std::alloc::System.realloc(ptr, layout, new_size)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: std::alloc::Layout) {
        std::alloc::System.dealloc(ptr, layout)
    }
}

/// Heap operations (alloc/alloc_zeroed/realloc; frees don't count)
/// performed by this thread so far. Always available; only meaningful
/// when [`CountingAlloc`] is the registered global allocator.
pub fn thread_alloc_ops() -> u64 {
    ALLOC_OPS.with(|c| c.get())
}

/// Run `f` and return how many heap operations this thread performed
/// inside it, alongside `f`'s result.
pub fn count_allocs<R>(f: impl FnOnce() -> R) -> (u64, R) {
    let before = thread_alloc_ops();
    let out = f();
    (thread_alloc_ops() - before, out)
}

/// Human-readable time formatting.
pub fn fmt_time(s: f64) -> String {
    if s >= 1.0 {
        format!("{s:.3} s")
    } else if s >= 1e-3 {
        format!("{:.3} ms", s * 1e3)
    } else if s >= 1e-6 {
        format!("{:.3} us", s * 1e6)
    } else {
        format!("{:.1} ns", s * 1e9)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something_positive() {
        std::env::set_var("BENCH_QUICK", "1");
        let mut b = Bench::new("selftest").with_samples(5);
        let mut x = 0u64;
        b.bench("noop-ish", || {
            x = x.wrapping_add(core::hint::black_box(1));
        });
        let results = b.finish();
        assert_eq!(results.len(), 1);
        assert!(results[0].median_s() > 0.0);
        assert!(results[0].median_s() < 1.0);
    }

    #[test]
    fn fmt_time_ranges() {
        assert!(fmt_time(2.0).ends_with(" s"));
        assert!(fmt_time(2e-3).ends_with(" ms"));
        assert!(fmt_time(2e-6).ends_with(" us"));
        assert!(fmt_time(2e-9).ends_with(" ns"));
    }

    #[test]
    fn count_allocs_passes_result_through() {
        // the lib test binary does not register CountingAlloc, so the
        // counter never moves here — the real zero-alloc pins live in
        // rust/tests/alloc_hotpath.rs, which does register it
        let (n, v) = count_allocs(|| vec![1, 2, 3].len());
        assert_eq!(v, 3);
        assert_eq!(n, 0);
    }

    #[test]
    fn record_value_keeps_value() {
        let mut b = Bench::new("values");
        b.record_value("carbon", 123.4, "kg");
        let r = b.finish();
        assert_eq!(r[0].samples, vec![123.4]);
    }
}
