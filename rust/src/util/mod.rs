//! Foundation substrates built from scratch for the offline environment:
//! deterministic RNG, JSON, statistics, CSV, scoped parallelism, a
//! property-testing helper, a criterion-like bench harness, and the
//! tiled per-datacenter storage behind the L-generic evaluator.

pub mod benchkit;
pub mod csv;
pub mod dcvec;
pub mod histogram;
pub mod json;
pub mod propkit;
pub mod rng;
pub mod stats;
pub mod threadpool;
