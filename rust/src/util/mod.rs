//! Foundation substrates built from scratch for the offline environment:
//! deterministic RNG, JSON, statistics, CSV, scoped parallelism, a
//! property-testing helper and a criterion-like bench harness.

pub mod benchkit;
pub mod csv;
pub mod json;
pub mod propkit;
pub mod rng;
pub mod stats;
pub mod threadpool;
