//! Log-bucketed latency histogram: constant-space p50/p95/p99 over
//! unbounded streams, mergeable across threads/connections.
//!
//! Buckets grow geometrically (8 per octave, ~9% width), so any quantile
//! is answered with bounded *relative* error — the right contract for
//! latencies spanning sub-millisecond warm hits to multi-second cold
//! loads. Exact min/max are tracked on the side so p0/p100 are exact and
//! interior quantiles can be clamped into the observed range. Two
//! histograms with the same fixed layout merge by adding counts, which is
//! what lets per-connection loadgen threads and per-class server metrics
//! aggregate without retaining raw samples.

/// Smallest resolvable latency, seconds (0.1 ms). Everything below lands
/// in bucket 0.
const LO_S: f64 = 1e-4;
/// Buckets per factor-of-two; relative bucket width 2^(1/8) - 1 ~ 9%.
const PER_OCTAVE: usize = 8;
/// 23 octaves above LO_S: covers up to ~840 s before saturating the top
/// bucket (exact max is still reported via the side channel).
const N_BUCKETS: usize = 23 * PER_OCTAVE;

/// A fixed-layout log-bucketed histogram of non-negative samples
/// (seconds, though the unit is the caller's business).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct LatencyHistogram {
    /// Lazily allocated to keep an empty histogram at ~0 bytes (ledgers
    /// carry one per epoch; most sim paths never record into it).
    counts: Vec<u64>,
    n: u64,
    sum: f64,
    min: f64,
    max: f64,
}

fn bucket_index(x: f64) -> usize {
    if x <= LO_S {
        return 0;
    }
    let i = ((x / LO_S).log2() * PER_OCTAVE as f64).floor();
    (i as usize).min(N_BUCKETS - 1)
}

/// Lower bound of bucket `i`, seconds.
fn bucket_lo(i: usize) -> f64 {
    LO_S * 2f64.powf(i as f64 / PER_OCTAVE as f64)
}

impl LatencyHistogram {
    pub fn new() -> LatencyHistogram {
        LatencyHistogram::default()
    }

    /// Record one sample. Negative values clamp to 0; non-finite values
    /// are dropped (a NaN latency is a measurement bug, not a tail).
    pub fn record(&mut self, x: f64) {
        if !x.is_finite() {
            return;
        }
        let x = x.max(0.0);
        if self.counts.is_empty() {
            self.counts = vec![0; N_BUCKETS];
            self.min = x;
            self.max = x;
        } else {
            self.min = self.min.min(x);
            self.max = self.max.max(x);
        }
        self.counts[bucket_index(x)] += 1;
        self.n += 1;
        self.sum += x;
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.sum / self.n as f64
        }
    }

    pub fn min(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.min
        }
    }

    pub fn max(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.max
        }
    }

    /// Quantile estimate, `q` in [0, 1]. Walks the cumulative counts to
    /// the target rank and interpolates linearly inside the hit bucket;
    /// the result is clamped to the exact observed [min, max], so
    /// `quantile(0.0)` and `quantile(1.0)` are exact.
    pub fn quantile(&self, q: f64) -> f64 {
        if self.n == 0 {
            return 0.0;
        }
        let q = q.clamp(0.0, 1.0);
        let target = (q * self.n as f64).ceil().max(1.0) as u64;
        let mut cum = 0u64;
        for (i, &c) in self.counts.iter().enumerate() {
            if c == 0 {
                continue;
            }
            if cum + c >= target {
                let frac = (target - cum) as f64 / c as f64;
                let lo = bucket_lo(i);
                let hi = bucket_lo(i + 1);
                let v = lo + (hi - lo) * frac;
                return v.clamp(self.min, self.max);
            }
            cum += c;
        }
        self.max
    }

    pub fn p50(&self) -> f64 {
        self.quantile(0.50)
    }

    pub fn p95(&self) -> f64 {
        self.quantile(0.95)
    }

    pub fn p99(&self) -> f64 {
        self.quantile(0.99)
    }

    /// Fold `other` into `self`. Layouts are identical by construction,
    /// so this is bucket-wise addition; merge(a, b) observes exactly the
    /// union of both sample streams.
    pub fn merge(&mut self, other: &LatencyHistogram) {
        if other.n == 0 {
            return;
        }
        if self.n == 0 {
            *self = other.clone();
            return;
        }
        for (a, b) in self.counts.iter_mut().zip(&other.counts) {
            *a += b;
        }
        self.n += other.n;
        self.sum += other.sum;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;
    use crate::util::stats::percentile;

    /// Bucket width bounds the relative error of interior quantiles.
    const REL_TOL: f64 = 0.10;

    #[test]
    fn empty_histogram_is_all_zeros() {
        let h = LatencyHistogram::new();
        assert_eq!(h.count(), 0);
        assert_eq!(h.mean(), 0.0);
        assert_eq!(h.p50(), 0.0);
        assert_eq!(h.p99(), 0.0);
        assert_eq!(h.min(), 0.0);
        assert_eq!(h.max(), 0.0);
    }

    #[test]
    fn single_sample_every_quantile_is_that_sample() {
        let mut h = LatencyHistogram::new();
        h.record(0.042);
        for q in [0.0, 0.5, 0.95, 0.99, 1.0] {
            assert_eq!(h.quantile(q), 0.042, "q={q}");
        }
        assert_eq!(h.count(), 1);
        assert!((h.mean() - 0.042).abs() < 1e-15);
    }

    #[test]
    fn quantiles_match_exact_percentiles_within_bucket_width() {
        // lognormal-ish latencies spanning ~3 decades, the serve-path shape
        let mut rng = Rng::new(7);
        let mut h = LatencyHistogram::new();
        let mut xs = Vec::new();
        for _ in 0..20_000 {
            let x = rng.lognormal(-(3.5f64.ln()), 0.8);
            h.record(x);
            xs.push(x);
        }
        for q in [0.10, 0.50, 0.90, 0.95, 0.99] {
            let exact = percentile(&xs, q * 100.0);
            let est = h.quantile(q);
            let rel = (est - exact).abs() / exact;
            assert!(
                rel <= REL_TOL,
                "q={q}: est {est} vs exact {exact} (rel {rel:.3})"
            );
        }
        // side-channel extremes are exact
        let (lo, hi) = crate::util::stats::min_max(&xs);
        assert_eq!(h.min(), lo);
        assert_eq!(h.max(), hi);
        assert_eq!(h.quantile(0.0), lo);
        assert_eq!(h.quantile(1.0), hi);
    }

    #[test]
    fn merge_equals_combined_stream() {
        let mut rng = Rng::new(11);
        let (mut a, mut b, mut all) = (
            LatencyHistogram::new(),
            LatencyHistogram::new(),
            LatencyHistogram::new(),
        );
        for i in 0..5_000 {
            let x = rng.exponential(20.0) + 1e-3;
            if i % 3 == 0 {
                a.record(x);
            } else {
                b.record(x);
            }
            all.record(x);
        }
        a.merge(&b);
        assert_eq!(a, all, "merge must be exactly the union of streams");
        // merging into / from empty is the identity
        let mut empty = LatencyHistogram::new();
        empty.merge(&all);
        assert_eq!(empty, all);
        let mut c = all.clone();
        c.merge(&LatencyHistogram::new());
        assert_eq!(c, all);
    }

    #[test]
    fn out_of_range_samples_saturate_not_panic() {
        let mut h = LatencyHistogram::new();
        h.record(0.0); // below LO_S: bucket 0
        h.record(1e-9);
        h.record(1e9); // above the top bucket: saturates
        h.record(-5.0); // clamps to 0
        h.record(f64::NAN); // dropped
        h.record(f64::INFINITY); // dropped
        assert_eq!(h.count(), 4);
        assert_eq!(h.min(), 0.0);
        assert_eq!(h.max(), 1e9, "exact max survives bucket saturation");
        assert_eq!(h.quantile(1.0), 1e9);
        assert!(h.quantile(0.25) >= 0.0);
    }

    #[test]
    fn quantiles_are_monotone_in_q() {
        let mut rng = Rng::new(13);
        let mut h = LatencyHistogram::new();
        for _ in 0..2_000 {
            h.record(rng.range(1e-4, 10.0));
        }
        let mut prev = 0.0;
        for i in 0..=20 {
            let q = i as f64 / 20.0;
            let v = h.quantile(q);
            assert!(v >= prev, "q={q}: {v} < {prev}");
            prev = v;
        }
    }
}
