//! Tiny CSV writer/reader for traces and experiment outputs.
//!
//! Quoting rules: fields containing `,`, `"` or newlines are quoted with
//! doubled inner quotes — enough for our own round-trips and for external
//! plotting tools.

use std::fs::File;
use std::io::{self, BufRead, BufReader, BufWriter, Write};
use std::path::Path;

/// Streaming CSV writer.
pub struct CsvWriter<W: Write> {
    w: W,
    cols: usize,
}

impl CsvWriter<BufWriter<File>> {
    pub fn create<P: AsRef<Path>>(path: P, header: &[&str]) -> io::Result<Self> {
        let f = File::create(path)?;
        CsvWriter::new(BufWriter::new(f), header)
    }
}

impl<W: Write> CsvWriter<W> {
    pub fn new(mut w: W, header: &[&str]) -> io::Result<Self> {
        write_row(&mut w, header)?;
        Ok(CsvWriter {
            w,
            cols: header.len(),
        })
    }

    pub fn row(&mut self, fields: &[String]) -> io::Result<()> {
        assert_eq!(fields.len(), self.cols, "csv row width mismatch");
        let refs: Vec<&str> = fields.iter().map(|s| s.as_str()).collect();
        write_row(&mut self.w, &refs)
    }

    pub fn row_f64(&mut self, fields: &[f64]) -> io::Result<()> {
        let strs: Vec<String> = fields.iter().map(|x| format!("{x}")).collect();
        self.row(&strs)
    }

    pub fn finish(mut self) -> io::Result<()> {
        self.w.flush()
    }
}

fn write_row<W: Write>(w: &mut W, fields: &[&str]) -> io::Result<()> {
    for (i, f) in fields.iter().enumerate() {
        if i > 0 {
            w.write_all(b",")?;
        }
        if f.contains(',') || f.contains('"') || f.contains('\n') {
            w.write_all(b"\"")?;
            w.write_all(f.replace('"', "\"\"").as_bytes())?;
            w.write_all(b"\"")?;
        } else {
            w.write_all(f.as_bytes())?;
        }
    }
    w.write_all(b"\n")
}

/// Parse a single CSV line (quoted fields supported).
pub fn parse_line(line: &str) -> Vec<String> {
    let mut out = Vec::new();
    let mut field = String::new();
    let mut chars = line.chars().peekable();
    let mut in_quotes = false;
    while let Some(c) = chars.next() {
        if in_quotes {
            if c == '"' {
                if chars.peek() == Some(&'"') {
                    field.push('"');
                    chars.next();
                } else {
                    in_quotes = false;
                }
            } else {
                field.push(c);
            }
        } else if c == '"' && field.is_empty() {
            in_quotes = true;
        } else if c == ',' {
            out.push(std::mem::take(&mut field));
        } else {
            field.push(c);
        }
    }
    out.push(field);
    out
}

/// Read a whole CSV file: (header, rows).
pub fn read_file<P: AsRef<Path>>(
    path: P,
) -> io::Result<(Vec<String>, Vec<Vec<String>>)> {
    let f = File::open(path)?;
    let mut lines = BufReader::new(f).lines();
    let header = match lines.next() {
        Some(h) => parse_line(&h?),
        None => return Ok((Vec::new(), Vec::new())),
    };
    let mut rows = Vec::new();
    for line in lines {
        let line = line?;
        if line.is_empty() {
            continue;
        }
        rows.push(parse_line(&line));
    }
    Ok((header, rows))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_trip_with_quoting() {
        let mut buf = Vec::new();
        {
            let mut w =
                CsvWriter::new(&mut buf, &["a", "b,comma", "c"]).unwrap();
            w.row(&[
                "plain".into(),
                "has,comma".into(),
                "has\"quote".into(),
            ])
            .unwrap();
            w.finish().unwrap();
        }
        let text = String::from_utf8(buf).unwrap();
        let mut lines = text.lines();
        assert_eq!(
            parse_line(lines.next().unwrap()),
            vec!["a", "b,comma", "c"]
        );
        assert_eq!(
            parse_line(lines.next().unwrap()),
            vec!["plain", "has,comma", "has\"quote"]
        );
    }

    #[test]
    fn parse_simple() {
        assert_eq!(parse_line("1,2,3"), vec!["1", "2", "3"]);
        assert_eq!(parse_line("a,,c"), vec!["a", "", "c"]);
    }

    #[test]
    #[should_panic(expected = "width mismatch")]
    fn width_checked() {
        let mut buf = Vec::new();
        let mut w = CsvWriter::new(&mut buf, &["a", "b"]).unwrap();
        let _ = w.row(&["only-one".into()]);
    }
}
