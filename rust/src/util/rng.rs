//! Deterministic PRNG substrate: xoshiro256++ seeded via SplitMix64.
//!
//! The offline build environment provides no `rand` crate, and determinism
//! matters here anyway: every simulation, trace, and optimizer run is keyed
//! by an explicit `u64` seed so experiments are exactly reproducible.

/// SplitMix64 — used to expand a single `u64` seed into xoshiro state.
#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// xoshiro256++ generator (Blackman & Vigna). Passes BigCrush; tiny state.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    /// cached second Box-Muller sample
    gauss_spare: Option<f64>,
}

impl Rng {
    /// Create a generator from a seed. Distinct seeds give independent
    /// streams for all practical purposes.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s, gauss_spare: None }
    }

    /// Derive a child generator (stable: depends only on parent seed + tag).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9E37_79B9_7F4A_7C15))
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0]
            .wrapping_add(s[3])
            .rotate_left(23)
            .wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f64 in [lo, hi).
    #[inline]
    pub fn range(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Uniform usize in [0, n). Uses Lemire's rejection-free-ish method.
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // 128-bit multiply keeps the modulo bias below 2^-64
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }

    /// Uniform integer in [lo, hi] inclusive.
    #[inline]
    pub fn int(&mut self, lo: i64, hi: i64) -> i64 {
        debug_assert!(lo <= hi);
        lo + self.below((hi - lo + 1) as usize) as i64
    }

    /// Bernoulli trial.
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal via Box-Muller (cached pair).
    pub fn gauss(&mut self) -> f64 {
        if let Some(z) = self.gauss_spare.take() {
            return z;
        }
        // avoid log(0)
        let u1 = (1.0 - self.f64()).max(1e-300);
        let u2 = self.f64();
        let r = (-2.0 * u1.ln()).sqrt();
        let (s, c) = (2.0 * std::f64::consts::PI * u2).sin_cos();
        self.gauss_spare = Some(r * s);
        r * c
    }

    /// Normal with mean / std.
    #[inline]
    pub fn normal(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.gauss()
    }

    /// Log-normal: exp(N(mu, sigma)).
    #[inline]
    pub fn lognormal(&mut self, mu: f64, sigma: f64) -> f64 {
        self.normal(mu, sigma).exp()
    }

    /// Exponential with the given rate (mean = 1/rate).
    #[inline]
    pub fn exponential(&mut self, rate: f64) -> f64 {
        debug_assert!(rate > 0.0);
        -(1.0 - self.f64()).max(1e-300).ln() / rate
    }

    /// Poisson sample (Knuth for small lambda, normal approx above 64).
    pub fn poisson(&mut self, lambda: f64) -> u64 {
        if lambda <= 0.0 {
            return 0;
        }
        if lambda > 64.0 {
            let x = self.normal(lambda, lambda.sqrt());
            return x.max(0.0).round() as u64;
        }
        let l = (-lambda).exp();
        let mut k = 0u64;
        let mut p = 1.0;
        loop {
            p *= self.f64();
            if p <= l {
                return k;
            }
            k += 1;
        }
    }

    /// Gamma(shape, scale=1) via Marsaglia-Tsang (shape >= 0.01).
    pub fn gamma(&mut self, shape: f64) -> f64 {
        if shape < 1.0 {
            // boost: Gamma(a) = Gamma(a+1) * U^(1/a)
            let g = self.gamma(shape + 1.0);
            return g * self.f64().powf(1.0 / shape.max(1e-3));
        }
        let d = shape - 1.0 / 3.0;
        let c = 1.0 / (9.0 * d).sqrt();
        loop {
            let x = self.gauss();
            let v = (1.0 + c * x).powi(3);
            if v <= 0.0 {
                continue;
            }
            let u = self.f64();
            if u < 1.0 - 0.0331 * x.powi(4)
                || u.ln() < 0.5 * x * x + d * (1.0 - v + v.ln())
            {
                return d * v;
            }
        }
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Pick a uniformly random element.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len())]
    }

    /// Weighted index sample; weights need not be normalised.
    pub fn weighted(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        if total <= 0.0 {
            return self.below(weights.len());
        }
        let mut x = self.f64() * total;
        for (i, w) in weights.iter().enumerate() {
            x -= w;
            if x <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut r = Rng::new(9);
        let mut seen = [false; 10];
        for _ in 0..1_000 {
            seen[r.below(10)] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn gauss_moments() {
        let mut r = Rng::new(11);
        let n = 200_000;
        let xs: Vec<f64> = (0..n).map(|_| r.gauss()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>()
            / n as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.03, "var {var}");
    }

    #[test]
    fn poisson_mean_matches_lambda() {
        let mut r = Rng::new(13);
        for &lam in &[0.5, 3.0, 20.0, 200.0] {
            let n = 20_000;
            let s: u64 = (0..n).map(|_| r.poisson(lam)).sum();
            let mean = s as f64 / n as f64;
            assert!(
                (mean - lam).abs() < lam.max(1.0) * 0.06,
                "lambda {lam} mean {mean}"
            );
        }
    }

    #[test]
    fn exponential_mean() {
        let mut r = Rng::new(15);
        let n = 100_000;
        let m: f64 = (0..n).map(|_| r.exponential(2.0)).sum::<f64>() / n as f64;
        assert!((m - 0.5).abs() < 0.01, "mean {m}");
    }

    #[test]
    fn gamma_mean_matches_shape() {
        let mut r = Rng::new(17);
        for &shape in &[0.5, 1.0, 4.0] {
            let n = 50_000;
            let m: f64 =
                (0..n).map(|_| r.gamma(shape)).sum::<f64>() / n as f64;
            assert!((m - shape).abs() < 0.1 * shape.max(1.0), "{shape} {m}");
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(19);
        let mut xs: Vec<u32> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn weighted_respects_weights() {
        let mut r = Rng::new(21);
        let w = [0.0, 1.0, 3.0];
        let mut counts = [0usize; 3];
        for _ in 0..40_000 {
            counts[r.weighted(&w)] += 1;
        }
        assert_eq!(counts[0], 0);
        let ratio = counts[2] as f64 / counts[1] as f64;
        assert!((ratio - 3.0).abs() < 0.25, "ratio {ratio}");
    }

    #[test]
    fn fork_streams_are_stable_and_distinct() {
        let mut a = Rng::new(5);
        let mut b = Rng::new(5);
        let mut fa = a.fork(1);
        let mut fb = b.fork(1);
        assert_eq!(fa.next_u64(), fb.next_u64());
        let mut fc = Rng::new(5).fork(2);
        assert_ne!(fa.next_u64(), fc.next_u64());
    }
}
