//! Small statistics toolkit used across the simulator, optimizer and the
//! bench harness: summary stats, percentiles, normalisation, online Welford.

/// Arithmetic mean; 0 for an empty slice.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        0.0
    } else {
        xs.iter().sum::<f64>() / xs.len() as f64
    }
}

/// Population standard deviation.
pub fn std_dev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64)
        .sqrt()
}

/// Percentile (linear interpolation), q in [0, 100]. Sorts a copy.
pub fn percentile(xs: &[f64], q: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v: Vec<f64> = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    percentile_sorted(&v, q)
}

/// Percentile on an already-sorted slice.
pub fn percentile_sorted(v: &[f64], q: f64) -> f64 {
    if v.is_empty() {
        return 0.0;
    }
    let pos = (q / 100.0) * (v.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        let frac = pos - lo as f64;
        v[lo] * (1.0 - frac) + v[hi] * frac
    }
}

/// Min/max of a slice (NaN-free input assumed).
pub fn min_max(xs: &[f64]) -> (f64, f64) {
    xs.iter().fold((f64::INFINITY, f64::NEG_INFINITY), |(lo, hi), &x| {
        (lo.min(x), hi.max(x))
    })
}

/// Scale values to [0, 1] with the given bounds; constant input maps to 0.
pub fn normalize(x: f64, lo: f64, hi: f64) -> f64 {
    if hi - lo <= 0.0 {
        0.0
    } else {
        ((x - lo) / (hi - lo)).clamp(0.0, 1.0)
    }
}

/// Online mean/variance (Welford). Numerically stable for long streams.
#[derive(Clone, Debug, Default)]
pub struct Welford {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Welford {
    pub fn new() -> Self {
        Welford {
            n: 0,
            mean: 0.0,
            m2: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.mean
        }
    }

    pub fn var(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / self.n as f64
        }
    }

    pub fn std(&self) -> f64 {
        self.var().sqrt()
    }

    pub fn min(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.min
        }
    }

    pub fn max(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.max
        }
    }

    pub fn merge(&mut self, other: &Welford) {
        if other.n == 0 {
            return;
        }
        if self.n == 0 {
            *self = other.clone();
            return;
        }
        let n = self.n + other.n;
        let d = other.mean - self.mean;
        self.m2 += other.m2
            + d * d * (self.n as f64 * other.n as f64) / n as f64;
        self.mean += d * other.n as f64 / n as f64;
        self.n = n;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }
}

/// Simple ordinary least squares y = a + b*x; returns (a, b).
pub fn linear_fit(xs: &[f64], ys: &[f64]) -> (f64, f64) {
    assert_eq!(xs.len(), ys.len());
    let n = xs.len() as f64;
    if xs.is_empty() {
        return (0.0, 0.0);
    }
    let mx = mean(xs);
    let my = mean(ys);
    let mut num = 0.0;
    let mut den = 0.0;
    for (x, y) in xs.iter().zip(ys) {
        num += (x - mx) * (y - my);
        den += (x - mx) * (x - mx);
    }
    if den.abs() < 1e-30 || n < 2.0 {
        (my, 0.0)
    } else {
        let b = num / den;
        (my - b * mx, b)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_std() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        assert!((mean(&xs) - 5.0).abs() < 1e-12);
        assert!((std_dev(&xs) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn percentiles() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 4.0);
        assert!((percentile(&xs, 50.0) - 2.5).abs() < 1e-12);
        assert_eq!(percentile(&[], 50.0), 0.0);
    }

    #[test]
    fn normalize_clamps() {
        assert_eq!(normalize(5.0, 0.0, 10.0), 0.5);
        assert_eq!(normalize(-1.0, 0.0, 10.0), 0.0);
        assert_eq!(normalize(11.0, 0.0, 10.0), 1.0);
        assert_eq!(normalize(3.0, 2.0, 2.0), 0.0);
    }

    #[test]
    fn welford_matches_batch() {
        let xs: Vec<f64> = (0..1000).map(|i| (i as f64).sin() * 3.0).collect();
        let mut w = Welford::new();
        for &x in &xs {
            w.push(x);
        }
        assert!((w.mean() - mean(&xs)).abs() < 1e-10);
        assert!((w.std() - std_dev(&xs)).abs() < 1e-10);
        let (lo, hi) = min_max(&xs);
        assert_eq!(w.min(), lo);
        assert_eq!(w.max(), hi);
    }

    #[test]
    fn welford_merge() {
        let xs: Vec<f64> = (0..500).map(|i| i as f64 * 0.1).collect();
        let mut a = Welford::new();
        let mut b = Welford::new();
        for &x in &xs[..200] {
            a.push(x);
        }
        for &x in &xs[200..] {
            b.push(x);
        }
        a.merge(&b);
        assert!((a.mean() - mean(&xs)).abs() < 1e-10);
        assert!((a.std() - std_dev(&xs)).abs() < 1e-10);
    }

    #[test]
    fn linear_fit_recovers_line() {
        let xs: Vec<f64> = (0..100).map(|i| i as f64).collect();
        let ys: Vec<f64> = xs.iter().map(|x| 3.0 + 2.0 * x).collect();
        let (a, b) = linear_fit(&xs, &ys);
        assert!((a - 3.0).abs() < 1e-9);
        assert!((b - 2.0).abs() < 1e-9);
    }
}
