//! Scoped data-parallel helpers on a persistent worker pool (no rayon in
//! the offline image).
//!
//! The first parallel call lazily spawns a process-wide pool of workers;
//! afterwards `par_map` / `par_for_each_mut` dispatch chunk tasks over a
//! shared channel instead of spawning OS threads per call — at SLIT's hot
//! path granularity (hundreds of sub-microsecond plan evaluations per
//! batch) per-call `thread::scope` spawning used to cost more than the work
//! itself. Both helpers preserve item order, fall back to the serial path
//! for small inputs, and run serially when invoked *from* a pool worker so
//! nested parallelism cannot deadlock the fixed-size pool.
//!
//! Determinism: chunk results are written into disjoint, position-stable
//! output slots, so for a pure `f` the result is bit-identical to the
//! serial path regardless of worker count or scheduling order (see
//! rust/tests/determinism.rs for the end-to-end regression).

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex, OnceLock};

/// Below this many items per chunk, dispatch overhead dominates; inputs
/// smaller than two chunks take the serial path outright.
const MIN_CHUNK: usize = 16;

/// 0 = no override (use SLIT_THREADS env or the hardware count).
static THREAD_OVERRIDE: AtomicUsize = AtomicUsize::new(0);

thread_local! {
    /// Set inside pool workers; parallel helpers invoked from a worker run
    /// serially instead of re-entering the (finite) pool.
    static IN_POOL: std::cell::Cell<bool> = const { std::cell::Cell::new(false) };
}

/// Force the logical thread count used by the parallel helpers (tests use
/// 1 vs many to pin down determinism). 0 restores the default.
pub fn set_thread_override(n: usize) {
    THREAD_OVERRIDE.store(n, Ordering::SeqCst);
}

/// Physical worker count: cores, capped (also the pool size).
pub fn hardware_threads() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(4)
        .min(16)
}

/// Number of logical worker threads to use: the override if set, else the
/// `SLIT_THREADS` environment variable (read once — this sits on the
/// per-dispatch hot path and env lookups take a process-global lock), else
/// the hardware count.
pub fn default_threads() -> usize {
    let forced = THREAD_OVERRIDE.load(Ordering::SeqCst);
    if forced > 0 {
        return forced;
    }
    static ENV_THREADS: OnceLock<Option<usize>> = OnceLock::new();
    let env = *ENV_THREADS.get_or_init(|| {
        std::env::var("SLIT_THREADS")
            .ok()
            .and_then(|v| v.parse::<usize>().ok())
            .filter(|&n| n > 0)
    });
    env.unwrap_or_else(hardware_threads)
}

type Task = Box<dyn FnOnce() + Send + 'static>;

struct Pool {
    tx: Mutex<Sender<Task>>,
}

static POOL: OnceLock<Pool> = OnceLock::new();

fn pool() -> &'static Pool {
    POOL.get_or_init(|| {
        let (tx, rx) = channel::<Task>();
        let rx = Arc::new(Mutex::new(rx));
        for i in 0..hardware_threads() {
            let rx = Arc::clone(&rx);
            std::thread::Builder::new()
                .name(format!("slit-pool-{i}"))
                .spawn(move || worker_loop(&rx))
                .expect("spawn pool worker");
        }
        Pool { tx: Mutex::new(tx) }
    })
}

fn worker_loop(rx: &Mutex<Receiver<Task>>) {
    IN_POOL.with(|c| c.set(true));
    loop {
        // Hold the receiver lock only while pulling one task; the blocked
        // recv() hands tasks out one at a time (natural load balancing).
        let task = match rx.lock() {
            Ok(guard) => guard.recv(),
            Err(_) => return,
        };
        match task {
            // A panic inside `f` must not kill the worker: the caller
            // notices via its unfilled output slot (see DoneGuard).
            Ok(task) => {
                let _ = std::panic::catch_unwind(
                    std::panic::AssertUnwindSafe(move || task()),
                );
            }
            Err(_) => return, // all senders gone: process shutting down
        }
    }
}

fn submit(task: Task) {
    pool()
        .tx
        .lock()
        .expect("pool sender poisoned")
        .send(task)
        .expect("pool workers gone");
}

/// Signals chunk completion to the dispatching caller even when the chunk
/// task panics or is dropped unrun: the wrapper in [`run_scoped`] stores
/// the task's outcome (capturing the original panic message) before the
/// guard drops, and dropping sends whatever is stored — so exactly one
/// signal per task, on every path.
struct DoneGuard {
    tx: Sender<Result<(), String>>,
    outcome: Result<(), String>,
}

impl Drop for DoneGuard {
    fn drop(&mut self) {
        let outcome =
            std::mem::replace(&mut self.outcome, Ok(()));
        let _ = self.tx.send(outcome);
    }
}

/// Best-effort extraction of a panic payload's message.
fn panic_message(payload: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

/// True when the calling code should not fan out (single logical thread,
/// or already running on a pool worker).
fn must_run_serial() -> bool {
    default_threads() <= 1 || IN_POOL.with(|c| c.get())
}

/// Tracks submitted chunk tasks and drains their completion signals — also
/// on unwind (Drop), which closes the soundness gap of the lifetime-erased
/// tasks: if anything panics in the dispatch loop after some tasks are
/// already in flight, the guard still blocks until every such task has
/// finished (or been dropped, which fires its DoneGuard) before the
/// caller's borrows can die. `pending` is incremented *before* submit, and
/// every panic path inside submit ends with the task being dropped, so the
/// signal count always matches. Drop never panics (unwind-safe); the
/// normal path re-raises a recorded worker panic via [`run_scoped`].
struct PendingJobs<'a> {
    rx: &'a Receiver<Result<(), String>>,
    pending: usize,
    first_error: Option<String>,
}

impl PendingJobs<'_> {
    fn new(rx: &Receiver<Result<(), String>>) -> PendingJobs<'_> {
        PendingJobs {
            rx,
            pending: 0,
            first_error: None,
        }
    }

    fn drain(&mut self) {
        while self.pending > 0 {
            match self.rx.recv() {
                Ok(Ok(())) => {}
                Ok(Err(msg)) => {
                    self.first_error.get_or_insert(msg);
                }
                Err(_) => {
                    self.first_error
                        .get_or_insert_with(|| "pool disconnected".into());
                }
            }
            self.pending -= 1;
        }
    }
}

impl Drop for PendingJobs<'_> {
    fn drop(&mut self) {
        self.drain();
    }
}

/// Dispatch a batch of lifetime-bound tasks to the pool and block until
/// every one has finished; a worker panic is re-raised here with its
/// original message. This is the single home of the lifetime-erasing
/// `transmute` both parallel helpers build on.
fn run_scoped(tasks: Vec<Box<dyn FnOnce() + Send + '_>>) {
    let (done_tx, done_rx) = channel::<Result<(), String>>();
    let mut pending = PendingJobs::new(&done_rx);
    for task in tasks {
        let mut done = DoneGuard {
            tx: done_tx.clone(),
            outcome: Err("task dropped before running".into()),
        };
        let wrapped: Box<dyn FnOnce() + Send + '_> = Box::new(move || {
            let result = std::panic::catch_unwind(
                std::panic::AssertUnwindSafe(task),
            );
            done.outcome = result.map_err(|p| panic_message(&*p));
        });
        // SAFETY: the borrows captured by `wrapped` stay alive until one
        // completion signal per submitted task has been received — by the
        // explicit drain below on the normal path, or by `pending`'s Drop
        // on any unwind (including a panic inside `submit` itself, whose
        // dropped task still fires its DoneGuard) — and DoneGuard sends
        // exactly once at the end of the task's life (run, unwound, or
        // dropped unrun). So no task can touch the borrows of the caller's
        // frame after they die.
        let wrapped: Task = unsafe { std::mem::transmute(wrapped) };
        pending.pending += 1;
        submit(wrapped);
    }
    pending.drain();
    if let Some(msg) = pending.first_error.take() {
        panic!("parallel worker panicked: {msg}");
    }
}

/// Run a small batch of heterogeneous scoped tasks on the pool, blocking
/// until every one has finished. Unlike [`par_map`], there is no
/// minimum-size threshold: this exists for coarse-grained fan-outs (one
/// task per *region* in the decomposed SLIT search) whose item counts sit
/// far below `par_map`'s chunking cutoff. When the logical thread count is
/// 1 or the caller is itself a pool worker, the tasks run serially **in
/// submission order** on the calling thread — which, combined with each
/// task writing only its own position-stable output slot, is what makes
/// callers bit-deterministic regardless of thread count. A panic inside a
/// task is re-raised here on both paths.
pub fn run_tasks(tasks: Vec<Box<dyn FnOnce() + Send + '_>>) {
    if must_run_serial() || tasks.len() < 2 {
        for task in tasks {
            task();
        }
        return;
    }
    run_scoped(tasks);
}

/// Parallel map over a slice preserving order.
pub fn par_map<T, U, F>(items: &[T], f: F) -> Vec<U>
where
    T: Sync,
    U: Send,
    F: Fn(&T) -> U + Sync,
{
    if must_run_serial() || items.len() < 2 * MIN_CHUNK {
        return items.iter().map(|x| f(x)).collect();
    }
    let threads = default_threads();
    let chunk = items.len().div_ceil(threads).max(MIN_CHUNK);
    let mut out: Vec<Option<U>> = Vec::with_capacity(items.len());
    out.resize_with(items.len(), || None);

    {
        let f = &f;
        let mut rest = out.as_mut_slice();
        let mut tasks: Vec<Box<dyn FnOnce() + Send + '_>> =
            Vec::with_capacity(items.len() / chunk + 1);
        for chunk_items in items.chunks(chunk) {
            let (head, tail) = rest.split_at_mut(chunk_items.len());
            rest = tail;
            tasks.push(Box::new(move || {
                for (slot, item) in head.iter_mut().zip(chunk_items) {
                    *slot = Some(f(item));
                }
            }));
        }
        // run_scoped blocks until every task has finished, so the borrows
        // of `items`, `f`, and `out` the tasks carry cannot dangle
        run_scoped(tasks);
    }
    out.into_iter().map(|x| x.expect("worker filled slot")).collect()
}

/// Parallel in-place transform over mutable chunks (order-stable).
pub fn par_for_each_mut<T, F>(items: &mut [T], f: F)
where
    T: Send,
    F: Fn(&mut T) + Sync,
{
    if must_run_serial() || items.len() < 2 * MIN_CHUNK {
        items.iter_mut().for_each(|x| f(x));
        return;
    }
    let threads = default_threads();
    let chunk = items.len().div_ceil(threads).max(MIN_CHUNK);
    let f = &f;
    let mut tasks: Vec<Box<dyn FnOnce() + Send + '_>> =
        Vec::with_capacity(items.len() / chunk + 1);
    for chunk_items in items.chunks_mut(chunk) {
        tasks.push(Box::new(move || {
            for item in chunk_items {
                f(item);
            }
        }));
    }
    run_scoped(tasks);
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Serialises tests that mutate the process-global thread override.
    static OVERRIDE_LOCK: Mutex<()> = Mutex::new(());

    #[test]
    fn par_map_matches_serial() {
        let xs: Vec<u64> = (0..10_000).collect();
        let par = par_map(&xs, |&x| x * x + 1);
        let ser: Vec<u64> = xs.iter().map(|&x| x * x + 1).collect();
        assert_eq!(par, ser);
    }

    #[test]
    fn par_map_small_input_takes_serial_path() {
        // below 2 * MIN_CHUNK the serial fallback runs on the caller thread
        let xs: Vec<i32> = (0..(2 * MIN_CHUNK as i32 - 1)).collect();
        let caller = std::thread::current().id();
        let ids = par_map(&xs, |&x| {
            assert_eq!(std::thread::current().id(), caller);
            x + 1
        });
        assert_eq!(ids.len(), xs.len());
        assert_eq!(ids[0], 1);
    }

    #[test]
    fn par_map_preserves_order() {
        // results land at their input positions even though chunks finish
        // in arbitrary order
        let xs: Vec<usize> = (0..5_000).collect();
        let out = par_map(&xs, |&x| x * 3);
        for (i, &v) in out.iter().enumerate() {
            assert_eq!(v, i * 3);
        }
    }

    #[test]
    fn par_map_non_divisible_chunking() {
        // lengths that do not divide evenly across threads/chunks must not
        // drop or duplicate items
        for n in [
            2 * MIN_CHUNK,
            2 * MIN_CHUNK + 1,
            257,
            1000,
            1001,
            MIN_CHUNK * 17 + 5,
        ] {
            let xs: Vec<u64> = (0..n as u64).collect();
            let out = par_map(&xs, |&x| x + 10);
            assert_eq!(out.len(), n);
            assert!(out.iter().enumerate().all(|(i, &v)| v == i as u64 + 10));
        }
    }

    #[test]
    fn par_for_each_mut_applies_everywhere() {
        let mut xs: Vec<u64> = (0..5_000).collect();
        par_for_each_mut(&mut xs, |x| *x += 7);
        assert!(xs.iter().enumerate().all(|(i, &x)| x == i as u64 + 7));
    }

    #[test]
    fn par_for_each_mut_non_divisible_and_order() {
        let mut xs: Vec<usize> = (0..(MIN_CHUNK * 13 + 3)).collect();
        par_for_each_mut(&mut xs, |x| *x = *x * 2 + 1);
        assert!(xs.iter().enumerate().all(|(i, &x)| x == i * 2 + 1));
    }

    #[test]
    fn empty_inputs() {
        let xs: Vec<u32> = vec![];
        assert!(par_map(&xs, |&x| x).is_empty());
        let mut ys: Vec<u32> = vec![];
        par_for_each_mut(&mut ys, |_| {});
    }

    #[test]
    fn nested_parallel_calls_complete() {
        // inner par_map calls run serially on pool workers (no deadlock)
        let xs: Vec<u64> = (0..256).collect();
        let out = par_map(&xs, |&x| {
            let inner: Vec<u64> = (0..64).collect();
            par_map(&inner, |&y| y + x).iter().sum::<u64>()
        });
        assert_eq!(out.len(), 256);
        assert_eq!(out[0], (0..64).sum::<u64>());
    }

    #[test]
    fn thread_override_forces_serial_and_is_deterministic() {
        let _g = OVERRIDE_LOCK.lock().unwrap_or_else(|e| e.into_inner());
        let xs: Vec<u64> = (0..4_096).collect();
        set_thread_override(1);
        let caller = std::thread::current().id();
        let serial = par_map(&xs, |&x| {
            assert_eq!(std::thread::current().id(), caller);
            x.wrapping_mul(0x9E37_79B9)
        });
        set_thread_override(8);
        let parallel = par_map(&xs, |&x| x.wrapping_mul(0x9E37_79B9));
        set_thread_override(0);
        assert_eq!(serial, parallel);
    }

    #[test]
    fn worker_panics_propagate_to_caller() {
        // a panicking closure must abort the call (serial path re-raises
        // directly; pool path re-raises via the DoneGuard ok flag), never
        // return partially-filled results
        let xs: Vec<u64> = (0..256).collect();
        let result = std::panic::catch_unwind(|| {
            par_map(&xs, |&x| {
                if x == 200 {
                    panic!("boom");
                }
                x
            })
        });
        assert!(result.is_err());
        // the pool survives the panic and keeps serving
        let ok = par_map(&xs, |&x| x + 1);
        assert_eq!(ok.len(), 256);
    }

    #[test]
    fn run_tasks_fills_position_stable_slots_on_both_paths() {
        // the fan-out primitive behind the region-decomposed search: a
        // handful of tasks (far below par_map's chunking cutoff) must run
        // on the pool when threads are available and serially in
        // submission order when forced single-threaded — with identical
        // results either way
        fn fan_out() -> Vec<u64> {
            let mut out = vec![0u64; 5];
            {
                let mut rest = out.as_mut_slice();
                let mut tasks: Vec<Box<dyn FnOnce() + Send + '_>> =
                    Vec::new();
                for i in 0..5u64 {
                    let (head, tail) = rest.split_at_mut(1);
                    rest = tail;
                    tasks.push(Box::new(move || {
                        head[0] = i * i + 7;
                    }));
                }
                run_tasks(tasks);
            }
            out
        }
        let _g = OVERRIDE_LOCK.lock().unwrap_or_else(|e| e.into_inner());
        set_thread_override(1);
        let serial = fan_out();
        set_thread_override(8);
        let parallel = fan_out();
        set_thread_override(0);
        let auto = fan_out();
        assert_eq!(serial, parallel);
        assert_eq!(serial, auto);
        assert_eq!(serial, vec![7, 8, 11, 16, 23]);
    }

    #[test]
    fn run_tasks_propagates_panics_and_handles_empty() {
        run_tasks(Vec::new()); // empty batch is a no-op
        let result = std::panic::catch_unwind(|| {
            let tasks: Vec<Box<dyn FnOnce() + Send + '_>> = vec![
                Box::new(|| {}),
                Box::new(|| panic!("region task boom")),
                Box::new(|| {}),
            ];
            run_tasks(tasks);
        });
        assert!(result.is_err());
        // the pool survives and keeps serving
        let xs: Vec<u64> = (0..256).collect();
        assert_eq!(par_map(&xs, |&x| x + 1).len(), 256);
    }

    #[test]
    fn many_sequential_batches_reuse_the_pool() {
        // regression for pool lifetime: thousands of dispatches must not
        // exhaust resources the way per-call thread spawning would
        for round in 0..200u64 {
            let xs: Vec<u64> = (0..128).collect();
            let out = par_map(&xs, |&x| x + round);
            assert_eq!(out[127], 127 + round);
        }
    }
}
