//! Scoped data-parallel helpers (no rayon in the offline image).
//!
//! `par_map` fans a slice out over `std::thread::scope` workers with static
//! chunking; `par_for_each_mut` does the same over mutable chunks. Both fall
//! back to the serial path for small inputs where spawn overhead dominates.

/// Number of worker threads to use (cores, capped).
pub fn default_threads() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(4)
        .min(16)
}

/// Parallel map over a slice preserving order.
pub fn par_map<T, U, F>(items: &[T], f: F) -> Vec<U>
where
    T: Sync,
    U: Send,
    F: Fn(&T) -> U + Sync,
{
    let threads = default_threads();
    if items.len() < 2 * threads || threads == 1 {
        return items.iter().map(|x| f(x)).collect();
    }
    let chunk = items.len().div_ceil(threads);
    let mut out: Vec<Option<U>> = Vec::with_capacity(items.len());
    out.resize_with(items.len(), || None);

    std::thread::scope(|s| {
        let mut rest = out.as_mut_slice();
        for (ci, chunk_items) in items.chunks(chunk).enumerate() {
            let (head, tail) = rest.split_at_mut(chunk_items.len());
            rest = tail;
            let f = &f;
            let base = ci * chunk;
            let _ = base;
            s.spawn(move || {
                for (slot, item) in head.iter_mut().zip(chunk_items) {
                    *slot = Some(f(item));
                }
            });
        }
    });
    out.into_iter().map(|x| x.expect("worker filled slot")).collect()
}

/// Parallel in-place transform over mutable chunks.
pub fn par_for_each_mut<T, F>(items: &mut [T], f: F)
where
    T: Send,
    F: Fn(&mut T) + Sync,
{
    let threads = default_threads();
    if items.len() < 2 * threads || threads == 1 {
        items.iter_mut().for_each(|x| f(x));
        return;
    }
    let chunk = items.len().div_ceil(threads);
    std::thread::scope(|s| {
        for chunk_items in items.chunks_mut(chunk) {
            let f = &f;
            s.spawn(move || {
                for item in chunk_items {
                    f(item);
                }
            });
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn par_map_matches_serial() {
        let xs: Vec<u64> = (0..10_000).collect();
        let par = par_map(&xs, |&x| x * x + 1);
        let ser: Vec<u64> = xs.iter().map(|&x| x * x + 1).collect();
        assert_eq!(par, ser);
    }

    #[test]
    fn par_map_small_input() {
        let xs = [1, 2, 3];
        assert_eq!(par_map(&xs, |&x| x + 1), vec![2, 3, 4]);
    }

    #[test]
    fn par_for_each_mut_applies_everywhere() {
        let mut xs: Vec<u64> = (0..5_000).collect();
        par_for_each_mut(&mut xs, |x| *x += 7);
        assert!(xs.iter().enumerate().all(|(i, &x)| x == i as u64 + 7));
    }

    #[test]
    fn empty_inputs() {
        let xs: Vec<u32> = vec![];
        assert!(par_map(&xs, |&x| x).is_empty());
        let mut ys: Vec<u32> = vec![];
        par_for_each_mut(&mut ys, |_| {});
    }
}
