//! Scheduling-plan representation.
//!
//! A plan is the unit the SLIT metaheuristic searches over: for every
//! request class k (origin region x model) a distribution over datacenters,
//! i.e. a row-stochastic matrix `a[k][l]` — the fraction of class-k
//! requests routed to datacenter l in the upcoming epoch (§4: "workload
//! assignment to each location"; within a location the local round-robin
//! scheduler takes over).

use crate::util::rng::Rng;

/// Renormalise one row slice to sum to 1 (clamping negatives to 0).
/// Shared by [`Plan`] and [`PlanBatch`] so the arena-generated candidates
/// are bit-identical to the equivalent `Plan`-method moves.
pub fn normalize_row_in_place(row: &mut [f64]) {
    let mut sum = 0.0;
    for v in row.iter_mut() {
        if *v < 0.0 {
            *v = 0.0;
        }
        sum += *v;
    }
    if sum <= 1e-15 {
        let u = 1.0 / row.len() as f64;
        row.iter_mut().for_each(|v| *v = u);
    } else {
        row.iter_mut().for_each(|v| *v /= sum);
    }
}

/// Directed move on one row slice: shift `frac` of every other cell's mass
/// toward `to`, then renormalise the row.
pub fn shift_row_toward(row: &mut [f64], to: usize, frac: f64) {
    for l in 0..row.len() {
        if l != to {
            let take = row[l] * frac;
            row[l] -= take;
            row[to] += take;
        }
    }
    normalize_row_in_place(row);
}

/// Local-search perturbation applied in place to a flattened matrix:
/// shift up to `step` of mass in a few random rows from one DC to another,
/// renormalising only the rows actually modified. Returns the touched-row
/// bitmask (bit k set = row k changed), which is what lets the delta
/// evaluator rescore the move in O(|touched| * L) instead of O(K * L).
///
/// The RNG call sequence matches the historical `Plan::perturbed` exactly;
/// the only behavioural difference is that untouched rows keep their exact
/// bit pattern instead of paying a no-op renormalisation.
pub fn perturb_in_place(
    a: &mut [f64],
    classes: usize,
    dcs: usize,
    step: f64,
    rng: &mut Rng,
) -> u64 {
    debug_assert_eq!(a.len(), classes * dcs);
    assert!(
        classes <= 64,
        "touched-row bitmask supports at most 64 classes, got {classes}"
    );
    let touched = 1 + rng.below(classes.max(1));
    let mut mask = 0u64;
    for _ in 0..touched {
        let k = rng.below(classes);
        let from = rng.below(dcs);
        let to = rng.below(dcs);
        if from == to {
            continue;
        }
        let row = &mut a[k * dcs..(k + 1) * dcs];
        let amount = (row[from] * rng.range(0.0, step)).min(row[from]);
        row[from] -= amount;
        row[to] += amount;
        mask |= 1 << k;
    }
    for k in 0..classes {
        if (mask >> k) & 1 == 1 {
            normalize_row_in_place(&mut a[k * dcs..(k + 1) * dcs]);
        }
    }
    mask
}

/// Row-stochastic assignment matrix, flattened `[k * dcs + l]`.
#[derive(Clone, Debug, PartialEq)]
pub struct Plan {
    pub classes: usize,
    pub dcs: usize,
    a: Vec<f64>,
}

impl Plan {
    /// The "evenly distributed" extreme plan (Algorithm 1 init).
    pub fn uniform(classes: usize, dcs: usize) -> Plan {
        Plan {
            classes,
            dcs,
            a: vec![1.0 / dcs as f64; classes * dcs],
        }
    }

    /// The "only one location" extreme plan (Algorithm 1 init).
    pub fn one_dc(classes: usize, dcs: usize, dc: usize) -> Plan {
        let mut p = Plan {
            classes,
            dcs,
            a: vec![0.0; classes * dcs],
        };
        for k in 0..classes {
            p.a[k * dcs + dc] = 1.0;
        }
        p
    }

    /// Build a plan directly from a flattened `[k * dcs + l]` matrix. The
    /// region-decomposed search uses this to stitch per-region sub-rows
    /// into one global plan before the canonical rescore; rows are
    /// renormalised so the result is row-stochastic even when the merge
    /// weights carry rounding slack.
    pub fn from_flat(classes: usize, dcs: usize, a: Vec<f64>) -> Plan {
        assert_eq!(
            a.len(),
            classes * dcs,
            "from_flat: flat length must be classes * dcs"
        );
        let mut p = Plan { classes, dcs, a };
        p.normalize();
        p
    }

    /// Random plan: Dirichlet(alpha)-distributed rows (sparse for small
    /// alpha, which matches how real schedulers concentrate load).
    pub fn random(classes: usize, dcs: usize, alpha: f64, rng: &mut Rng) -> Plan {
        let mut p = Plan {
            classes,
            dcs,
            a: vec![0.0; classes * dcs],
        };
        for k in 0..classes {
            for l in 0..dcs {
                p.a[k * dcs + l] = rng.gamma(alpha).max(1e-12);
            }
        }
        p.normalize();
        p
    }

    #[inline]
    pub fn get(&self, k: usize, l: usize) -> f64 {
        self.a[k * self.dcs + l]
    }

    #[inline]
    pub fn set(&mut self, k: usize, l: usize, v: f64) {
        self.a[k * self.dcs + l] = v;
    }

    pub fn row(&self, k: usize) -> &[f64] {
        &self.a[k * self.dcs..(k + 1) * self.dcs]
    }

    pub fn as_slice(&self) -> &[f64] {
        &self.a
    }

    /// Renormalise every row to sum to 1 (clamping negatives to 0).
    pub fn normalize(&mut self) {
        for k in 0..self.classes {
            self.normalize_row(k);
        }
    }

    /// Renormalise a single row (others untouched).
    pub fn normalize_row(&mut self, k: usize) {
        normalize_row_in_place(&mut self.a[k * self.dcs..(k + 1) * self.dcs]);
    }

    /// True when every row sums to 1 within tolerance and is non-negative.
    pub fn is_valid(&self) -> bool {
        (0..self.classes).all(|k| {
            let row = self.row(k);
            let sum: f64 = row.iter().sum();
            row.iter().all(|&v| v >= -1e-12) && (sum - 1.0).abs() < 1e-6
        })
    }

    /// Local-search neighbour: shift `step` of mass in a few random rows
    /// from one DC to another, renormalising the touched rows.
    pub fn perturbed(&self, step: f64, rng: &mut Rng) -> Plan {
        self.perturbed_tracked(step, rng).0
    }

    /// [`Plan::perturbed`] plus the touched-row bitmask the delta evaluator
    /// needs to rescore the move in O(|touched| * L).
    pub fn perturbed_tracked(&self, step: f64, rng: &mut Rng) -> (Plan, u64) {
        let mut p = self.clone();
        let mask =
            perturb_in_place(&mut p.a, self.classes, self.dcs, step, rng);
        (p, mask)
    }

    /// Directed neighbour: move mass in row `k` toward DC `to`. Other rows
    /// are untouched (mass within row `k` is conserved by construction).
    pub fn shifted_toward(&self, k: usize, to: usize, frac: f64) -> Plan {
        let mut p = self.clone();
        shift_row_toward(&mut p.a[k * self.dcs..(k + 1) * self.dcs], to, frac);
        p
    }

    /// EA crossover (Algorithm 1 line 14): per-row arithmetic blend with a
    /// random mixing coefficient — children inherit whole-row traits.
    pub fn crossover(&self, other: &Plan, rng: &mut Rng) -> Plan {
        assert_eq!(self.classes, other.classes);
        assert_eq!(self.dcs, other.dcs);
        let mut child = self.clone();
        for k in 0..self.classes {
            let w = rng.f64();
            for l in 0..self.dcs {
                let v = w * self.get(k, l) + (1.0 - w) * other.get(k, l);
                child.set(k, l, v);
            }
        }
        child.normalize();
        child
    }

    /// EA mutation (Algorithm 1 line 15): random gene resampling.
    pub fn mutated(&self, rate: f64, rng: &mut Rng) -> Plan {
        let mut p = self.clone();
        for k in 0..self.classes {
            for l in 0..self.dcs {
                if rng.chance(rate) {
                    p.set(k, l, rng.gamma(0.5).max(1e-12));
                }
            }
        }
        p.normalize();
        p
    }

    /// L1 distance between plans (diversity metric for the archive).
    pub fn distance(&self, other: &Plan) -> f64 {
        self.a
            .iter()
            .zip(&other.a)
            .map(|(x, y)| (x - y).abs())
            .sum()
    }

    /// Flatten into the AOT layout: f32 row-major `[k][slot]` with `slots`
    /// padded DC columns (zeros beyond `self.dcs`).
    pub fn to_f32_padded(&self, slots: usize, out: &mut Vec<f32>) {
        debug_assert!(slots >= self.dcs);
        for k in 0..self.classes {
            for l in 0..self.dcs {
                out.push(self.get(k, l) as f32);
            }
            for _ in self.dcs..slots {
                out.push(0.0);
            }
        }
    }
}

/// Struct-of-arrays candidate arena for the SLIT local search: the merged
/// per-step neighbour batch lives in **one** contiguous `f64` buffer
/// (`[candidate][k * dcs + l]`) with a parallel touched-row bitmask per
/// candidate. Surrogate ranking, delta scoring, and trajectory capture all
/// read slices straight out of the arena; a `Plan` is materialised only
/// for the few candidates that actually survive (move acceptance, archive
/// entry). After [`PlanBatch::reserve`], generating a step's candidates
/// performs zero heap allocations (pinned by rust/tests/alloc_hotpath.rs).
#[derive(Debug)]
pub struct PlanBatch {
    classes: usize,
    dcs: usize,
    data: Vec<f64>,
    touched: Vec<u64>,
}

impl PlanBatch {
    pub fn new(classes: usize, dcs: usize) -> PlanBatch {
        assert!(
            classes <= 64,
            "touched-row bitmask supports at most 64 classes, got {classes}"
        );
        PlanBatch {
            classes,
            dcs,
            data: Vec::new(),
            touched: Vec::new(),
        }
    }

    /// Cells per candidate.
    #[inline]
    pub fn stride(&self) -> usize {
        self.classes * self.dcs
    }

    /// Candidates currently in the arena.
    pub fn len(&self) -> usize {
        self.touched.len()
    }

    pub fn is_empty(&self) -> bool {
        self.touched.is_empty()
    }

    /// Drop all candidates, keeping the allocations.
    pub fn clear(&mut self) {
        self.data.clear();
        self.touched.clear();
    }

    /// Pre-size for `candidates` entries so subsequent pushes stay
    /// allocation-free.
    pub fn reserve(&mut self, candidates: usize) {
        let cells = candidates.saturating_mul(self.stride());
        if self.data.capacity() < cells {
            self.data.reserve(cells - self.data.len());
        }
        if self.touched.capacity() < candidates {
            self.touched.reserve(candidates - self.touched.len());
        }
    }

    /// Flattened matrix of candidate `i`.
    #[inline]
    pub fn candidate(&self, i: usize) -> &[f64] {
        let s = self.stride();
        &self.data[i * s..(i + 1) * s]
    }

    /// Row `k` of candidate `i`.
    #[inline]
    pub fn row(&self, i: usize, k: usize) -> &[f64] {
        let s = self.stride();
        &self.data[i * s + k * self.dcs..i * s + (k + 1) * self.dcs]
    }

    /// Touched-row bitmask of candidate `i` (relative to the base plan it
    /// was generated from).
    #[inline]
    pub fn touched(&self, i: usize) -> u64 {
        self.touched[i]
    }

    /// One contiguous row-major view over candidates `lo..hi` (what
    /// `Gbdt::predict_batch_into` consumes).
    pub fn range_flat(&self, lo: usize, hi: usize) -> &[f64] {
        let s = self.stride();
        &self.data[lo * s..hi * s]
    }

    /// Materialise candidate `i` as an owned [`Plan`] (the only place a
    /// candidate pays for a heap allocation).
    pub fn to_plan(&self, i: usize) -> Plan {
        Plan {
            classes: self.classes,
            dcs: self.dcs,
            a: self.candidate(i).to_vec(),
        }
    }

    /// Copy `base` in as a new untouched candidate; returns its index.
    pub fn push_base(&mut self, base: &[f64]) -> usize {
        debug_assert_eq!(base.len(), self.stride());
        self.data.extend_from_slice(base);
        self.touched.push(0);
        self.touched.len() - 1
    }

    #[inline]
    fn row_mut(&mut self, i: usize, k: usize) -> &mut [f64] {
        let s = self.stride();
        let dcs = self.dcs;
        &mut self.data[i * s + k * dcs..i * s + (k + 1) * dcs]
    }

    /// Generate the SLIT move set for one population slot directly into
    /// the arena: `n` candidates cycling over the four neighbour kinds
    /// (two Dirichlet-ish perturbations, a directed shift toward a random
    /// DC, and a snap-to-vertex collapse onto the row argmax). Each
    /// candidate records the rows it touched, so the delta evaluator can
    /// rescore it against `cur`'s cached epoch aggregates in O(L) per
    /// touched row. The RNG call sequence per candidate matches the
    /// historical `Plan`-clone generation path.
    pub fn push_neighbors_of(
        &mut self,
        cur: &[f64],
        n: usize,
        step: f64,
        rng: &mut Rng,
    ) {
        for c in 0..n {
            let i = self.push_base(cur);
            match c % 4 {
                // directed move toward a random DC
                2 => {
                    let k = rng.below(self.classes);
                    let to = rng.below(self.dcs);
                    let frac = rng.range(0.2, 0.8);
                    shift_row_toward(self.row_mut(i, k), to, frac);
                    self.touched[i] = 1 << k;
                }
                // snap-to-vertex: collapse one row onto its argmax,
                // erasing residual routing mass (the single-objective
                // optima live on vertices)
                3 => {
                    let k = rng.below(self.classes);
                    let best = self
                        .row(i, k)
                        .iter()
                        .enumerate()
                        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                        .map(|(l, _)| l)
                        .unwrap_or(0);
                    shift_row_toward(self.row_mut(i, k), best, 1.0);
                    self.touched[i] = 1 << k;
                }
                _ => {
                    let (classes, dcs) = (self.classes, self.dcs);
                    let s = self.stride();
                    let cand = &mut self.data[i * s..(i + 1) * s];
                    self.touched[i] =
                        perturb_in_place(cand, classes, dcs, step, rng);
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::propkit;

    #[test]
    fn uniform_and_one_dc_are_valid() {
        let u = Plan::uniform(8, 12);
        assert!(u.is_valid());
        assert!((u.get(3, 7) - 1.0 / 12.0).abs() < 1e-12);
        let o = Plan::one_dc(8, 12, 4);
        assert!(o.is_valid());
        assert_eq!(o.get(2, 4), 1.0);
        assert_eq!(o.get(2, 5), 0.0);
    }

    #[test]
    fn random_plans_are_valid_property() {
        propkit::check(
            "random-plan-valid",
            0xA11CE,
            200,
            |r| Plan::random(8, 12, r.range(0.05, 2.0), r),
            |p| {
                if p.is_valid() {
                    Ok(())
                } else {
                    Err("row not stochastic".into())
                }
            },
        );
    }

    #[test]
    fn perturb_crossover_mutate_preserve_validity() {
        propkit::check(
            "plan-ops-valid",
            0xBEEF,
            200,
            |r| {
                let a = Plan::random(8, 12, 0.5, r);
                let b = Plan::random(8, 12, 0.5, r);
                let mut r2 = r.fork(1);
                let p = a.perturbed(0.4, &mut r2);
                let c = a.crossover(&b, &mut r2);
                let m = c.mutated(0.2, &mut r2);
                let s = m.shifted_toward(3, 5, 0.7);
                (p, c, m, s)
            },
            |(p, c, m, s)| {
                for (name, plan) in [
                    ("perturbed", p),
                    ("crossover", c),
                    ("mutated", m),
                    ("shifted", s),
                ] {
                    if !plan.is_valid() {
                        return Err(format!("{name} broke stochasticity"));
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn shifted_toward_concentrates() {
        let u = Plan::uniform(4, 6);
        let s = u.shifted_toward(2, 3, 0.5);
        assert!(s.get(2, 3) > u.get(2, 3));
        assert!(s.get(2, 0) < u.get(2, 0));
        // other rows untouched
        assert_eq!(s.row(1), u.row(1));
    }

    #[test]
    fn crossover_stays_within_parents_hull() {
        let mut rng = Rng::new(3);
        let a = Plan::random(4, 6, 0.5, &mut rng);
        let b = Plan::random(4, 6, 0.5, &mut rng);
        let c = a.crossover(&b, &mut rng);
        for k in 0..4 {
            for l in 0..6 {
                let lo = a.get(k, l).min(b.get(k, l)) - 1e-9;
                let hi = a.get(k, l).max(b.get(k, l)) + 1e-9;
                // blend preserves row sums at 1 so no renorm distortion
                assert!(c.get(k, l) >= lo && c.get(k, l) <= hi);
            }
        }
    }

    #[test]
    fn distance_zero_iff_equal() {
        let mut rng = Rng::new(4);
        let a = Plan::random(8, 12, 0.5, &mut rng);
        assert_eq!(a.distance(&a), 0.0);
        // a guaranteed-effective move: perturbed may legitimately draw a
        // no-op (from == to), and untouched rows now keep their exact bits
        let b = a.shifted_toward(2, 5, 0.9);
        assert!(a.distance(&b) > 0.0);
    }

    #[test]
    fn normalize_rescues_degenerate_rows() {
        let mut p = Plan::one_dc(2, 3, 0);
        p.set(1, 0, 0.0);
        p.set(1, 1, 0.0);
        p.set(1, 2, 0.0);
        p.normalize();
        assert!(p.is_valid());
        assert!((p.get(1, 1) - 1.0 / 3.0).abs() < 1e-12);
    }

    #[test]
    fn f32_padding_layout() {
        let p = Plan::one_dc(2, 3, 1);
        let mut out = Vec::new();
        p.to_f32_padded(5, &mut out);
        assert_eq!(out.len(), 2 * 5);
        assert_eq!(out[1], 1.0);
        assert_eq!(out[3], 0.0); // padded
        assert_eq!(out[4], 0.0);
        assert_eq!(out[5 + 1], 1.0);
    }

    #[test]
    fn perturbed_tracked_mask_covers_exactly_the_changed_rows() {
        propkit::check(
            "perturb-mask-exact",
            0x7AC5,
            200,
            |r| {
                let p = Plan::random(8, 12, 0.5, r);
                let mut r2 = r.fork(9);
                let (q, mask) = p.perturbed_tracked(0.4, &mut r2);
                (p, q, mask)
            },
            |(p, q, mask)| {
                for k in 0..p.classes {
                    let changed = p.row(k) != q.row(k);
                    let marked = (mask >> k) & 1 == 1;
                    // untouched rows must keep their exact bit pattern;
                    // a marked row may still be value-identical (the move
                    // can shift zero mass), never the other way round
                    if changed && !marked {
                        return Err(format!("row {k} changed but unmarked"));
                    }
                }
                if !q.is_valid() {
                    return Err("perturbed plan not row-stochastic".into());
                }
                Ok(())
            },
        );
    }

    #[test]
    fn shift_row_toward_matches_plan_method_bitwise() {
        let mut rng = Rng::new(11);
        let p = Plan::random(6, 9, 0.5, &mut rng);
        for k in 0..6 {
            let via_plan = p.shifted_toward(k, 4, 0.37);
            let mut row = p.row(k).to_vec();
            shift_row_toward(&mut row, 4, 0.37);
            assert_eq!(via_plan.row(k), &row[..]);
        }
    }

    #[test]
    fn plan_batch_neighbors_match_plan_clone_generation() {
        // the arena path and the historical Plan-clone path must produce
        // bit-identical candidates given the same RNG stream
        let mut rng = Rng::new(21);
        let cur = Plan::random(8, 12, 0.5, &mut rng);
        let n = 8;
        let step = 0.25;

        let mut arena = PlanBatch::new(8, 12);
        arena.reserve(n);
        let mut r1 = rng.fork(1);
        // fork() advances the parent, so clone the child for the replays
        let mut r2 = r1.clone();
        let mut r3 = r1.clone();
        arena.push_neighbors_of(cur.as_slice(), n, step, &mut r1);
        for c in 0..n {
            let (want, want_mask): (Plan, u64) = match c % 4 {
                2 => {
                    let k = r2.below(8);
                    let to = r2.below(12);
                    let frac = r2.range(0.2, 0.8);
                    (cur.shifted_toward(k, to, frac), 1 << k)
                }
                3 => {
                    let k = r2.below(8);
                    let best = cur
                        .row(k)
                        .iter()
                        .enumerate()
                        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                        .map(|(l, _)| l)
                        .unwrap();
                    (cur.shifted_toward(k, best, 1.0), 1 << k)
                }
                _ => cur.perturbed_tracked(step, &mut r2),
            };
            assert_eq!(arena.candidate(c), want.as_slice(), "candidate {c}");
            assert_eq!(arena.touched(c), want_mask, "mask {c}");
            assert_eq!(arena.to_plan(c), want);
        }
        assert_eq!(arena.len(), n);
        assert_eq!(arena.range_flat(0, n).len(), n * 8 * 12);
        // the shared reference generator the benches compare against
        // (util::benchkit::clone_path_neighbors) must agree with both
        let shared =
            crate::util::benchkit::clone_path_neighbors(&cur, n, step, &mut r3);
        for (c, w) in shared.iter().enumerate() {
            assert_eq!(arena.candidate(c), w.as_slice(), "shared ref {c}");
        }
    }

    #[test]
    fn plan_and_arena_are_fleet_size_generic() {
        // the stride is classes * dcs with no tile assumption: a 48-DC
        // plan (the global-fleet shape, past the evaluator's inline
        // DcVec tile) round-trips through every move primitive and the
        // arena exactly like a paper-sized one
        let (classes, dcs) = (8, 48);
        let mut rng = Rng::new(31);
        let cur = Plan::random(classes, dcs, 0.5, &mut rng);
        assert!(cur.is_valid());
        assert!(cur.shifted_toward(3, 47, 0.6).is_valid());
        let (p, mask) = cur.perturbed_tracked(0.4, &mut rng);
        assert!(p.is_valid());
        assert!(mask < 1 << classes);

        let mut arena = PlanBatch::new(classes, dcs);
        arena.reserve(8);
        let mut r1 = rng.fork(2);
        let mut r2 = r1.clone();
        arena.push_neighbors_of(cur.as_slice(), 8, 0.25, &mut r1);
        assert_eq!(arena.len(), 8);
        assert_eq!(arena.stride(), classes * dcs);
        let want = crate::util::benchkit::clone_path_neighbors(
            &cur, 8, 0.25, &mut r2,
        );
        for (c, w) in want.iter().enumerate() {
            assert_eq!(arena.candidate(c), w.as_slice(), "candidate {c}");
            assert!(arena.to_plan(c).is_valid());
        }
    }

    #[test]
    fn plan_batch_clear_keeps_capacity() {
        let mut arena = PlanBatch::new(4, 6);
        arena.reserve(16);
        let mut rng = Rng::new(5);
        let cur = Plan::uniform(4, 6);
        arena.push_neighbors_of(cur.as_slice(), 16, 0.3, &mut rng);
        assert_eq!(arena.len(), 16);
        let cap = arena.data.capacity();
        arena.clear();
        assert!(arena.is_empty());
        assert_eq!(arena.data.capacity(), cap);
    }
}
