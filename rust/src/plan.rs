//! Scheduling-plan representation.
//!
//! A plan is the unit the SLIT metaheuristic searches over: for every
//! request class k (origin region x model) a distribution over datacenters,
//! i.e. a row-stochastic matrix `a[k][l]` — the fraction of class-k
//! requests routed to datacenter l in the upcoming epoch (§4: "workload
//! assignment to each location"; within a location the local round-robin
//! scheduler takes over).

use crate::util::rng::Rng;

/// Row-stochastic assignment matrix, flattened `[k * dcs + l]`.
#[derive(Clone, Debug, PartialEq)]
pub struct Plan {
    pub classes: usize,
    pub dcs: usize,
    a: Vec<f64>,
}

impl Plan {
    /// The "evenly distributed" extreme plan (Algorithm 1 init).
    pub fn uniform(classes: usize, dcs: usize) -> Plan {
        Plan {
            classes,
            dcs,
            a: vec![1.0 / dcs as f64; classes * dcs],
        }
    }

    /// The "only one location" extreme plan (Algorithm 1 init).
    pub fn one_dc(classes: usize, dcs: usize, dc: usize) -> Plan {
        let mut p = Plan {
            classes,
            dcs,
            a: vec![0.0; classes * dcs],
        };
        for k in 0..classes {
            p.a[k * dcs + dc] = 1.0;
        }
        p
    }

    /// Random plan: Dirichlet(alpha)-distributed rows (sparse for small
    /// alpha, which matches how real schedulers concentrate load).
    pub fn random(classes: usize, dcs: usize, alpha: f64, rng: &mut Rng) -> Plan {
        let mut p = Plan {
            classes,
            dcs,
            a: vec![0.0; classes * dcs],
        };
        for k in 0..classes {
            for l in 0..dcs {
                p.a[k * dcs + l] = rng.gamma(alpha).max(1e-12);
            }
        }
        p.normalize();
        p
    }

    #[inline]
    pub fn get(&self, k: usize, l: usize) -> f64 {
        self.a[k * self.dcs + l]
    }

    #[inline]
    pub fn set(&mut self, k: usize, l: usize, v: f64) {
        self.a[k * self.dcs + l] = v;
    }

    pub fn row(&self, k: usize) -> &[f64] {
        &self.a[k * self.dcs..(k + 1) * self.dcs]
    }

    pub fn as_slice(&self) -> &[f64] {
        &self.a
    }

    /// Renormalise every row to sum to 1 (clamping negatives to 0).
    pub fn normalize(&mut self) {
        for k in 0..self.classes {
            self.normalize_row(k);
        }
    }

    /// Renormalise a single row (others untouched).
    pub fn normalize_row(&mut self, k: usize) {
        let row = &mut self.a[k * self.dcs..(k + 1) * self.dcs];
        let mut sum = 0.0;
        for v in row.iter_mut() {
            if *v < 0.0 {
                *v = 0.0;
            }
            sum += *v;
        }
        if sum <= 1e-15 {
            let u = 1.0 / row.len() as f64;
            row.iter_mut().for_each(|v| *v = u);
        } else {
            row.iter_mut().for_each(|v| *v /= sum);
        }
    }

    /// True when every row sums to 1 within tolerance and is non-negative.
    pub fn is_valid(&self) -> bool {
        (0..self.classes).all(|k| {
            let row = self.row(k);
            let sum: f64 = row.iter().sum();
            row.iter().all(|&v| v >= -1e-12) && (sum - 1.0).abs() < 1e-6
        })
    }

    /// Local-search neighbour: shift `step` of mass in a few random rows
    /// from one DC to another, renormalise.
    pub fn perturbed(&self, step: f64, rng: &mut Rng) -> Plan {
        let mut p = self.clone();
        let touched = 1 + rng.below(self.classes.max(1));
        for _ in 0..touched {
            let k = rng.below(self.classes);
            let from = rng.below(self.dcs);
            let to = rng.below(self.dcs);
            if from == to {
                continue;
            }
            let amount = (p.get(k, from) * rng.range(0.0, step)).min(p.get(k, from));
            p.set(k, from, p.get(k, from) - amount);
            p.set(k, to, p.get(k, to) + amount);
        }
        p.normalize();
        p
    }

    /// Directed neighbour: move mass in row `k` toward DC `to`. Other rows
    /// are untouched (mass within row `k` is conserved by construction).
    pub fn shifted_toward(&self, k: usize, to: usize, frac: f64) -> Plan {
        let mut p = self.clone();
        for l in 0..self.dcs {
            if l != to {
                let take = p.get(k, l) * frac;
                p.set(k, l, p.get(k, l) - take);
                p.set(k, to, p.get(k, to) + take);
            }
        }
        p.normalize_row(k);
        p
    }

    /// EA crossover (Algorithm 1 line 14): per-row arithmetic blend with a
    /// random mixing coefficient — children inherit whole-row traits.
    pub fn crossover(&self, other: &Plan, rng: &mut Rng) -> Plan {
        assert_eq!(self.classes, other.classes);
        assert_eq!(self.dcs, other.dcs);
        let mut child = self.clone();
        for k in 0..self.classes {
            let w = rng.f64();
            for l in 0..self.dcs {
                let v = w * self.get(k, l) + (1.0 - w) * other.get(k, l);
                child.set(k, l, v);
            }
        }
        child.normalize();
        child
    }

    /// EA mutation (Algorithm 1 line 15): random gene resampling.
    pub fn mutated(&self, rate: f64, rng: &mut Rng) -> Plan {
        let mut p = self.clone();
        for k in 0..self.classes {
            for l in 0..self.dcs {
                if rng.chance(rate) {
                    p.set(k, l, rng.gamma(0.5).max(1e-12));
                }
            }
        }
        p.normalize();
        p
    }

    /// L1 distance between plans (diversity metric for the archive).
    pub fn distance(&self, other: &Plan) -> f64 {
        self.a
            .iter()
            .zip(&other.a)
            .map(|(x, y)| (x - y).abs())
            .sum()
    }

    /// Flatten into the AOT layout: f32 row-major `[k][slot]` with `slots`
    /// padded DC columns (zeros beyond `self.dcs`).
    pub fn to_f32_padded(&self, slots: usize, out: &mut Vec<f32>) {
        debug_assert!(slots >= self.dcs);
        for k in 0..self.classes {
            for l in 0..self.dcs {
                out.push(self.get(k, l) as f32);
            }
            for _ in self.dcs..slots {
                out.push(0.0);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::propkit;

    #[test]
    fn uniform_and_one_dc_are_valid() {
        let u = Plan::uniform(8, 12);
        assert!(u.is_valid());
        assert!((u.get(3, 7) - 1.0 / 12.0).abs() < 1e-12);
        let o = Plan::one_dc(8, 12, 4);
        assert!(o.is_valid());
        assert_eq!(o.get(2, 4), 1.0);
        assert_eq!(o.get(2, 5), 0.0);
    }

    #[test]
    fn random_plans_are_valid_property() {
        propkit::check(
            "random-plan-valid",
            0xA11CE,
            200,
            |r| Plan::random(8, 12, r.range(0.05, 2.0), r),
            |p| {
                if p.is_valid() {
                    Ok(())
                } else {
                    Err("row not stochastic".into())
                }
            },
        );
    }

    #[test]
    fn perturb_crossover_mutate_preserve_validity() {
        propkit::check(
            "plan-ops-valid",
            0xBEEF,
            200,
            |r| {
                let a = Plan::random(8, 12, 0.5, r);
                let b = Plan::random(8, 12, 0.5, r);
                let mut r2 = r.fork(1);
                let p = a.perturbed(0.4, &mut r2);
                let c = a.crossover(&b, &mut r2);
                let m = c.mutated(0.2, &mut r2);
                let s = m.shifted_toward(3, 5, 0.7);
                (p, c, m, s)
            },
            |(p, c, m, s)| {
                for (name, plan) in [
                    ("perturbed", p),
                    ("crossover", c),
                    ("mutated", m),
                    ("shifted", s),
                ] {
                    if !plan.is_valid() {
                        return Err(format!("{name} broke stochasticity"));
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn shifted_toward_concentrates() {
        let u = Plan::uniform(4, 6);
        let s = u.shifted_toward(2, 3, 0.5);
        assert!(s.get(2, 3) > u.get(2, 3));
        assert!(s.get(2, 0) < u.get(2, 0));
        // other rows untouched
        assert_eq!(s.row(1), u.row(1));
    }

    #[test]
    fn crossover_stays_within_parents_hull() {
        let mut rng = Rng::new(3);
        let a = Plan::random(4, 6, 0.5, &mut rng);
        let b = Plan::random(4, 6, 0.5, &mut rng);
        let c = a.crossover(&b, &mut rng);
        for k in 0..4 {
            for l in 0..6 {
                let lo = a.get(k, l).min(b.get(k, l)) - 1e-9;
                let hi = a.get(k, l).max(b.get(k, l)) + 1e-9;
                // blend preserves row sums at 1 so no renorm distortion
                assert!(c.get(k, l) >= lo && c.get(k, l) <= hi);
            }
        }
    }

    #[test]
    fn distance_zero_iff_equal() {
        let mut rng = Rng::new(4);
        let a = Plan::random(8, 12, 0.5, &mut rng);
        assert_eq!(a.distance(&a), 0.0);
        let b = a.perturbed(0.5, &mut rng);
        assert!(a.distance(&b) > 0.0);
    }

    #[test]
    fn normalize_rescues_degenerate_rows() {
        let mut p = Plan::one_dc(2, 3, 0);
        p.set(1, 0, 0.0);
        p.set(1, 1, 0.0);
        p.set(1, 2, 0.0);
        p.normalize();
        assert!(p.is_valid());
        assert!((p.get(1, 1) - 1.0 / 3.0).abs() < 1e-12);
    }

    #[test]
    fn f32_padding_layout() {
        let p = Plan::one_dc(2, 3, 1);
        let mut out = Vec::new();
        p.to_f32_padded(5, &mut out);
        assert_eq!(out.len(), 2 * 5);
        assert_eq!(out[1], 1.0);
        assert_eq!(out[3], 0.0); // padded
        assert_eq!(out[4], 0.0);
        assert_eq!(out[5 + 1], 1.0);
    }
}
