//! Datacenter/cluster model: node-type capability derivation, the parameter
//! panels consumed by the analytic evaluator + AOT kernel, and the
//! aggregate capacity bookkeeping used by the discrete simulator.
//!
//! Heterogeneity (§3.2/§6): each site hosts six node types (2/4/8 x
//! A100/H100) whose GPUs pool memory. A node type can only serve a model
//! whose parameter memory fits the pooled memory (Eq. 1's capacity clause);
//! per-class throughput panels are node-count-weighted means over the
//! types that can serve the class.

use crate::config::{DatacenterSpec, NodeType, SystemConfig, MODELS};
use crate::power::GridSignals;
use crate::trace::EpochLoad;

/// Mutable per-run cluster topology: the live node counts a
/// [`crate::session::SimSession`] owns and [`ClusterAction`]s mutate
/// mid-run (rolling outages, node additions, brownouts). Derived from,
/// but no longer identical to, the static `SystemConfig` — panels and
/// capacity bookkeeping are rebuilt from this state every epoch.
#[derive(Clone, Debug, PartialEq)]
pub struct ClusterState {
    /// Config-derived counts, kept for exact restores: `[dc][node_type]`.
    baseline: Vec<Vec<usize>>,
    /// Live counts the current epoch runs against: `[dc][node_type]`.
    nodes: Vec<Vec<usize>>,
    /// Region of each site (so region-wide actions need no config).
    regions: Vec<usize>,
}

/// One mutation of the live cluster topology.
#[derive(Clone, Debug, PartialEq)]
pub enum ClusterAction {
    /// Scale every site in a region to `frac` of its baseline node count
    /// (`frac = 0.0` takes the region fully dark).
    ScaleRegion { region: usize, frac: f64 },
    /// Restore every site in a region to its baseline counts.
    RestoreRegion { region: usize },
    /// Scale one site to `frac` of its baseline node count (brownout).
    ScaleSite { dc: usize, frac: f64 },
    /// Restore one site to its baseline counts.
    RestoreSite { dc: usize },
    /// Replace one site's per-type node counts outright (node additions).
    SetSite { dc: usize, nodes_per_type: Vec<usize> },
    /// Inject a grid-telemetry fault into the session's [`crate::signals::
    /// SignalFeed`]. Topology-inert: `ClusterState::apply` treats it as a
    /// no-op — the session routes it to the feed instead, so telemetry
    /// faults flow through the same `ScenarioEvent` schedule as capacity
    /// faults.
    Signal(crate::signals::SignalFault),
}

impl ClusterState {
    pub fn from_config(cfg: &SystemConfig) -> ClusterState {
        let baseline: Vec<Vec<usize>> = cfg
            .datacenters
            .iter()
            .map(|d| d.nodes_per_type.clone())
            .collect();
        ClusterState {
            nodes: baseline.clone(),
            regions: cfg.datacenters.iter().map(|d| d.region).collect(),
            baseline,
        }
    }

    pub fn dcs(&self) -> usize {
        self.nodes.len()
    }

    /// Live per-type node counts of one site.
    pub fn nodes(&self, dc: usize) -> &[usize] {
        &self.nodes[dc]
    }

    pub fn total_nodes(&self, dc: usize) -> usize {
        self.nodes[dc].iter().sum()
    }

    /// Live total node count per site (the Fig. 5 capacity series).
    pub fn site_totals(&self) -> Vec<usize> {
        (0..self.dcs()).map(|l| self.total_nodes(l)).collect()
    }

    /// True when every site still matches its config-derived baseline.
    pub fn is_baseline(&self) -> bool {
        self.nodes == self.baseline
    }

    fn scale_site(&mut self, dc: usize, frac: f64) {
        let frac = frac.max(0.0);
        self.nodes[dc] = self.baseline[dc]
            .iter()
            .map(|&n| (n as f64 * frac).round() as usize)
            .collect();
    }

    pub fn apply(&mut self, action: &ClusterAction) {
        match action {
            ClusterAction::ScaleRegion { region, frac } => {
                for dc in 0..self.dcs() {
                    if self.regions[dc] == *region {
                        self.scale_site(dc, *frac);
                    }
                }
            }
            ClusterAction::RestoreRegion { region } => {
                for dc in 0..self.dcs() {
                    if self.regions[dc] == *region {
                        self.nodes[dc] = self.baseline[dc].clone();
                    }
                }
            }
            ClusterAction::ScaleSite { dc, frac } => self.scale_site(*dc, *frac),
            ClusterAction::RestoreSite { dc } => {
                self.nodes[*dc] = self.baseline[*dc].clone();
            }
            ClusterAction::SetSite { dc, nodes_per_type } => {
                // normalise to the site's node-type arity: every consumer
                // indexes by node-type, so a short vector is padded with
                // zeros and extra entries are dropped rather than letting
                // a malformed serve-time action panic the epoch clock
                let mut nodes = nodes_per_type.clone();
                nodes.resize(self.baseline[*dc].len(), 0);
                self.nodes[*dc] = nodes;
            }
            // telemetry faults never touch topology; the session owns the
            // SignalFeed they target
            ClusterAction::Signal(_) => {}
        }
    }
}

/// Can this node type serve this model at all (parameters + some KV fit)?
pub fn can_serve(nt: &NodeType, model_mem_gb: f64) -> bool {
    pooled_mem_gb(nt) >= model_mem_gb * 1.05
}

/// Pooled GPU memory of a node, GB (§3.2: GPUs pool their memory).
pub fn pooled_mem_gb(nt: &NodeType) -> f64 {
    nt.gpus as f64 * nt.gpu_mem_gb
}

/// Per-class parameter panels in the AOT kernel's layout (see
/// python/compile/kernels/ref.py for semantics).
#[derive(Clone, Debug)]
pub struct ClassPanels {
    pub classes: usize,
    pub dcs: usize,
    /// [K] requests, mean output tokens, model memory GB.
    pub n_req: Vec<f64>,
    pub tok_out: Vec<f64>,
    pub mem: Vec<f64>,
    /// [K * L] node throughput tokens/s; first-token seconds; router hops.
    pub thr: Vec<f64>,
    pub proc: Vec<f64>,
    pub hops: Vec<f64>,
}

/// Per-datacenter parameter panel (AOT `dc[8, L]` rows).
#[derive(Clone, Debug)]
pub struct DcPanels {
    pub dcs: usize,
    pub nodes: Vec<f64>,
    pub tdp: Vec<f64>,
    pub cop: Vec<f64>,
    pub tou: Vec<f64>,
    pub ci: Vec<f64>,
    pub wi: Vec<f64>,
    pub bw: Vec<f64>,
    pub unused_pr: Vec<f64>,
}

/// Mean node throughput for a model over an explicit per-type node-count
/// vector, restricted to types that can hold the model. tokens/s per node.
pub fn mean_node_throughput_n(
    cfg: &SystemConfig,
    nodes_per_type: &[usize],
    model: usize,
) -> f64 {
    let mem = cfg.models[model].param_mem_gb;
    let mut num = 0.0;
    let mut den = 0.0;
    for (ti, nt) in cfg.node_types.iter().enumerate() {
        if can_serve(nt, mem) {
            let n = nodes_per_type[ti] as f64;
            num += n * nt.thr_tokens_s[model];
            den += n;
        }
    }
    if den > 0.0 {
        num / den
    } else {
        0.0
    }
}

/// Mean node throughput for a model at a site, weighted by node counts and
/// restricted to types that can hold the model. tokens/s per node.
pub fn mean_node_throughput(
    cfg: &SystemConfig,
    dc: &DatacenterSpec,
    model: usize,
) -> f64 {
    mean_node_throughput_n(cfg, &dc.nodes_per_type, model)
}

/// Mean per-request decode rate over an explicit node-count vector.
pub fn mean_decode_rate_n(
    cfg: &SystemConfig,
    nodes_per_type: &[usize],
    model: usize,
) -> f64 {
    let mem = cfg.models[model].param_mem_gb;
    let mut num = 0.0;
    let mut den = 0.0;
    for (ti, nt) in cfg.node_types.iter().enumerate() {
        if can_serve(nt, mem) {
            let n = nodes_per_type[ti] as f64;
            num += n * nt.decode_tokens_s[model];
            den += n;
        }
    }
    if den > 0.0 {
        num / den
    } else {
        0.0
    }
}

/// Mean per-request decode rate at a site for a model, tokens/s.
pub fn mean_decode_rate(
    cfg: &SystemConfig,
    dc: &DatacenterSpec,
    model: usize,
) -> f64 {
    mean_decode_rate_n(cfg, &dc.nodes_per_type, model)
}

/// Node-count-weighted mean TDP over an explicit node-count vector, W.
pub fn mean_node_tdp_n(cfg: &SystemConfig, nodes_per_type: &[usize]) -> f64 {
    let mut num = 0.0;
    let mut den = 0.0;
    for (ti, nt) in cfg.node_types.iter().enumerate() {
        let n = nodes_per_type[ti] as f64;
        num += n * nt.tdp_w;
        den += n;
    }
    if den > 0.0 {
        num / den
    } else {
        0.0
    }
}

/// Node-count-weighted mean TDP at a site, W.
pub fn mean_node_tdp(cfg: &SystemConfig, dc: &DatacenterSpec) -> f64 {
    mean_node_tdp_n(cfg, &dc.nodes_per_type)
}

/// Build the evaluator panels for one epoch from the *live* cluster state
/// (per-epoch node counts may differ from the config when
/// [`ClusterAction`]s have fired).
///
/// `unused_pr` is the framework's power policy for nodes not serving load
/// this epoch: `pr_off` for schedulers that scale to zero (SLIT),
/// `pr_idle` for always-warm baselines (Splitwise).
pub fn build_panels_dyn(
    cfg: &SystemConfig,
    state: &ClusterState,
    signals: &GridSignals,
    epoch: usize,
    load: &EpochLoad,
    unused_pr: f64,
) -> (ClassPanels, DcPanels) {
    let (ci, wi, tou) = signals.at(epoch);
    build_panels_with(cfg, state, &ci, &wi, &tou, load, unused_pr)
}

/// Build the evaluator panels from *explicit* per-site grid values
/// instead of reading ground truth at an epoch — the seam the signal
/// plane uses to hand schedulers *believed* CI/WUE/TOU panels
/// (`signals::SignalFeed::view`) while ledger accounting stays on truth.
/// [`build_panels_dyn`] is exactly this over `signals.at(epoch)`.
pub fn build_panels_with(
    cfg: &SystemConfig,
    state: &ClusterState,
    ci: &[f64],
    wi: &[f64],
    tou: &[f64],
    load: &EpochLoad,
    unused_pr: f64,
) -> (ClassPanels, DcPanels) {
    let k_n = cfg.num_classes();
    let l_n = cfg.datacenters.len();
    let mut cp = ClassPanels {
        classes: k_n,
        dcs: l_n,
        n_req: vec![0.0; k_n],
        tok_out: vec![0.0; k_n],
        mem: vec![0.0; k_n],
        thr: vec![1.0; k_n * l_n],
        proc: vec![0.0; k_n * l_n],
        hops: vec![0.0; k_n * l_n],
    };
    for k in 0..k_n {
        let model = k % MODELS;
        let region = k / MODELS;
        let c = &load.classes[k];
        cp.n_req[k] = c.n_req;
        cp.tok_out[k] = c.tok_out;
        cp.mem[k] = cfg.models[model].param_mem_gb;
        for l in 0..l_n {
            let nodes = state.nodes(l);
            let thr = mean_node_throughput_n(cfg, nodes, model);
            let dec = mean_decode_rate_n(cfg, nodes, model);
            cp.thr[k * l_n + l] = thr.max(1e-9);
            cp.proc[k * l_n + l] = if dec > 0.0 { 1.0 / dec } else { 1e3 };
            cp.hops[k * l_n + l] = cfg.hops(region, l);
        }
    }

    let dp = DcPanels {
        dcs: l_n,
        nodes: (0..l_n).map(|l| state.total_nodes(l) as f64).collect(),
        tdp: (0..l_n)
            .map(|l| mean_node_tdp_n(cfg, state.nodes(l)))
            .collect(),
        cop: cfg.datacenters.iter().map(|d| d.cop).collect(),
        tou: tou.to_vec(),
        ci: ci.to_vec(),
        wi: wi.to_vec(),
        bw: cfg.datacenters.iter().map(|d| d.bw_gbs).collect(),
        unused_pr: vec![unused_pr; l_n],
    };
    (cp, dp)
}

/// Build the evaluator panels from the static config (the pre-`SimSession`
/// API, kept for call sites that never mutate capacity mid-run). Identical
/// to [`build_panels_dyn`] over `ClusterState::from_config(cfg)`.
pub fn build_panels(
    cfg: &SystemConfig,
    signals: &GridSignals,
    epoch: usize,
    load: &EpochLoad,
    unused_pr: f64,
) -> (ClassPanels, DcPanels) {
    build_panels_dyn(
        cfg,
        &ClusterState::from_config(cfg),
        signals,
        epoch,
        load,
        unused_pr,
    )
}

/// Aggregate per-(site, node-type) capacity bookkeeping for the discrete
/// simulator: tracks committed node-seconds within an epoch.
#[derive(Clone, Debug)]
pub struct DcCapacity {
    /// Node-seconds available per type this epoch.
    pub budget_s: Vec<f64>,
    /// Node-seconds committed per type.
    pub used_s: Vec<f64>,
    /// Nodes per type (copy of the spec).
    pub nodes: Vec<usize>,
}

impl DcCapacity {
    pub fn new(dc: &DatacenterSpec, epoch_s: f64) -> DcCapacity {
        DcCapacity::from_nodes(&dc.nodes_per_type, epoch_s)
    }

    /// Capacity over an explicit node-count vector (live cluster state).
    pub fn from_nodes(nodes_per_type: &[usize], epoch_s: f64) -> DcCapacity {
        DcCapacity {
            budget_s: nodes_per_type
                .iter()
                .map(|&n| n as f64 * epoch_s)
                .collect(),
            used_s: vec![0.0; nodes_per_type.len()],
            nodes: nodes_per_type.to_vec(),
        }
    }

    /// Commit `node_s` node-seconds on a type; returns false if exhausted.
    pub fn commit(&mut self, node_type: usize, node_s: f64) -> bool {
        if self.used_s[node_type] + node_s <= self.budget_s[node_type] {
            self.used_s[node_type] += node_s;
            true
        } else {
            false
        }
    }

    pub fn remaining_s(&self, node_type: usize) -> f64 {
        self.budget_s[node_type] - self.used_s[node_type]
    }

    /// Utilisation of a node type in [0, 1].
    pub fn utilization(&self, node_type: usize) -> f64 {
        if self.budget_s[node_type] <= 0.0 {
            return 1.0;
        }
        (self.used_s[node_type] / self.budget_s[node_type]).clamp(0.0, 1.0)
    }

    /// Whole-site utilisation.
    pub fn site_utilization(&self) -> f64 {
        let b: f64 = self.budget_s.iter().sum();
        if b <= 0.0 {
            return 1.0;
        }
        (self.used_s.iter().sum::<f64>() / b).clamp(0.0, 1.0)
    }

    /// Equivalent number of ON nodes per type (used node-seconds / epoch).
    pub fn on_nodes(&self, node_type: usize, epoch_s: f64) -> f64 {
        (self.used_s[node_type] / epoch_s).min(self.nodes[node_type] as f64)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SystemConfig;
    use crate::trace::Trace;

    #[test]
    fn small_model_fits_everywhere_large_needs_memory() {
        let cfg = SystemConfig::paper_default();
        for nt in &cfg.node_types {
            assert!(can_serve(nt, cfg.models[0].param_mem_gb), "{}", nt.name);
        }
        // 140 GB needs > 147 GB pooled: 2-GPU nodes (160 GB) qualify,
        // so every type should still serve it in the default config.
        let servable = cfg
            .node_types
            .iter()
            .filter(|nt| can_serve(nt, cfg.models[1].param_mem_gb))
            .count();
        assert_eq!(servable, 6);
        // but a hypothetical 1-GPU type would not
        let mut tiny = cfg.node_types[0].clone();
        tiny.gpus = 1;
        assert!(!can_serve(&tiny, cfg.models[1].param_mem_gb));
    }

    #[test]
    fn throughput_weighted_mean_in_range() {
        let cfg = SystemConfig::paper_default();
        let dc = &cfg.datacenters[0];
        for model in 0..MODELS {
            let thr = mean_node_throughput(&cfg, dc, model);
            let min = cfg
                .node_types
                .iter()
                .map(|n| n.thr_tokens_s[model])
                .fold(f64::INFINITY, f64::min);
            let max = cfg
                .node_types
                .iter()
                .map(|n| n.thr_tokens_s[model])
                .fold(0.0, f64::max);
            assert!(thr >= min && thr <= max, "model {model}: {thr}");
        }
    }

    #[test]
    fn panels_have_expected_shapes_and_ranges() {
        let cfg = SystemConfig::paper_default();
        let signals = GridSignals::generate(&cfg, 4, 1);
        let trace = Trace::generate(&cfg, 4, 1);
        let (cp, dp) = build_panels(&cfg, &signals, 2, &trace.epochs[2], 0.05);
        assert_eq!(cp.classes, cfg.num_classes());
        assert_eq!(cp.thr.len(), cp.classes * cp.dcs);
        assert!(cp.thr.iter().all(|&t| t > 0.0));
        assert!(cp.proc.iter().all(|&p| p > 0.0 && p < 10.0));
        assert_eq!(dp.nodes.len(), cfg.datacenters.len());
        assert!(dp.nodes.iter().all(|&n| n == 1000.0));
        assert!(dp.tdp.iter().all(|&t| t > 1000.0 && t < 7000.0));
        assert!(dp.unused_pr.iter().all(|&u| u == 0.05));
        // local DC has fewer hops than cross-region for class 0 (east-asia)
        let l_n = cp.dcs;
        let local = cfg.datacenters.iter().position(|d| d.region == 0).unwrap();
        let remote = cfg.datacenters.iter().position(|d| d.region == 3).unwrap();
        assert!(cp.hops[local] < cp.hops[remote]);
        let _ = l_n;
    }

    #[test]
    fn cluster_state_actions_scale_and_restore() {
        let cfg = SystemConfig::paper_default();
        let mut st = ClusterState::from_config(&cfg);
        assert!(st.is_baseline());
        let before: Vec<usize> = st.site_totals();
        st.apply(&ClusterAction::ScaleRegion { region: 2, frac: 0.0 });
        assert!(!st.is_baseline());
        for (l, d) in cfg.datacenters.iter().enumerate() {
            if d.region == 2 {
                assert_eq!(st.total_nodes(l), 0, "{}", d.name);
            } else {
                assert_eq!(st.total_nodes(l), before[l]);
            }
        }
        st.apply(&ClusterAction::RestoreRegion { region: 2 });
        assert!(st.is_baseline());
        // site-level brownout + explicit set
        st.apply(&ClusterAction::ScaleSite { dc: 0, frac: 0.5 });
        assert!(st.total_nodes(0) < before[0]);
        st.apply(&ClusterAction::SetSite {
            dc: 0,
            nodes_per_type: vec![1, 1, 1, 1, 1, 1],
        });
        assert_eq!(st.total_nodes(0), 6);
        // malformed arity is normalised, not propagated: short vectors
        // pad with zeros, long ones truncate
        st.apply(&ClusterAction::SetSite {
            dc: 0,
            nodes_per_type: vec![5],
        });
        assert_eq!(st.nodes(0).len(), cfg.node_types.len());
        assert_eq!(st.total_nodes(0), 5);
        st.apply(&ClusterAction::SetSite {
            dc: 0,
            nodes_per_type: vec![1; 99],
        });
        assert_eq!(st.nodes(0).len(), cfg.node_types.len());
        st.apply(&ClusterAction::RestoreSite { dc: 0 });
        assert!(st.is_baseline());
    }

    #[test]
    fn dyn_panels_match_static_on_baseline_state() {
        let cfg = SystemConfig::paper_default();
        let signals = GridSignals::generate(&cfg, 4, 1);
        let trace = Trace::generate(&cfg, 4, 1);
        let st = ClusterState::from_config(&cfg);
        let (cp_a, dp_a) =
            build_panels(&cfg, &signals, 2, &trace.epochs[2], 0.05);
        let (cp_b, dp_b) = build_panels_dyn(
            &cfg,
            &st,
            &signals,
            2,
            &trace.epochs[2],
            0.05,
        );
        assert_eq!(cp_a.thr, cp_b.thr);
        assert_eq!(cp_a.proc, cp_b.proc);
        assert_eq!(dp_a.nodes, dp_b.nodes);
        assert_eq!(dp_a.tdp, dp_b.tdp);
    }

    #[test]
    fn dyn_panels_track_outage_state() {
        let cfg = SystemConfig::paper_default();
        let signals = GridSignals::generate(&cfg, 4, 1);
        let trace = Trace::generate(&cfg, 4, 1);
        let mut st = ClusterState::from_config(&cfg);
        st.apply(&ClusterAction::ScaleRegion { region: 2, frac: 0.0 });
        let (_, dp) = build_panels_dyn(
            &cfg,
            &st,
            &signals,
            2,
            &trace.epochs[2],
            0.05,
        );
        for (l, d) in cfg.datacenters.iter().enumerate() {
            if d.region == 2 {
                assert_eq!(dp.nodes[l], 0.0, "{}", d.name);
            } else {
                assert!(dp.nodes[l] > 0.0);
            }
        }
    }

    /// Random well-formed [`ClusterAction`] over the small-test topology.
    fn gen_action(rng: &mut crate::util::rng::Rng, dcs: usize) -> ClusterAction {
        match rng.below(6) {
            0 => ClusterAction::ScaleRegion {
                region: rng.below(crate::config::REGIONS),
                frac: rng.range(0.0, 1.0),
            },
            1 => ClusterAction::RestoreRegion {
                region: rng.below(crate::config::REGIONS),
            },
            2 => ClusterAction::ScaleSite {
                dc: rng.below(dcs),
                frac: rng.range(0.0, 1.0),
            },
            3 => ClusterAction::RestoreSite { dc: rng.below(dcs) },
            4 => ClusterAction::SetSite {
                dc: rng.below(dcs),
                nodes_per_type: (0..6).map(|_| rng.below(11)).collect(),
            },
            // topology-inert by contract: the round-trip/panel properties
            // must hold with telemetry faults interleaved
            _ => ClusterAction::Signal(crate::signals::SignalFault::Freeze {
                site: rng.below(dcs),
                epochs: 1 + rng.below(8),
            }),
        }
    }

    #[test]
    fn prop_scale_then_restore_round_trips_to_baseline() {
        let cfg = SystemConfig::small_test();
        let dcs = cfg.datacenters.len();
        crate::util::propkit::check(
            "cluster-scale-restore-round-trip",
            0xC1,
            crate::util::propkit::DEFAULT_CASES,
            |rng| {
                (0..rng.below(12))
                    .map(|_| gen_action(rng, dcs))
                    .collect::<Vec<ClusterAction>>()
            },
            |actions| {
                let mut st = ClusterState::from_config(&cfg);
                for a in actions {
                    st.apply(a);
                }
                // restoring every region must erase any action history
                for region in 0..crate::config::REGIONS {
                    st.apply(&ClusterAction::RestoreRegion { region });
                }
                if st.is_baseline() {
                    Ok(())
                } else {
                    Err("restore-all did not reach baseline".into())
                }
            },
        );
    }

    #[test]
    fn prop_fractional_scaling_never_exceeds_baseline() {
        let cfg = SystemConfig::small_test();
        let dcs = cfg.datacenters.len();
        let baseline = ClusterState::from_config(&cfg);
        crate::util::propkit::check(
            "cluster-counts-bounded",
            0xC2,
            crate::util::propkit::DEFAULT_CASES,
            |rng| {
                // only shrinking/restoring actions (frac in [0, 1], no
                // SetSite growth): counts must stay within baseline
                (0..1 + rng.below(10))
                    .map(|_| match rng.below(4) {
                        0 => ClusterAction::ScaleRegion {
                            region: rng.below(crate::config::REGIONS),
                            frac: rng.range(0.0, 1.0),
                        },
                        1 => ClusterAction::RestoreRegion {
                            region: rng.below(crate::config::REGIONS),
                        },
                        2 => ClusterAction::ScaleSite {
                            dc: rng.below(dcs),
                            frac: rng.range(0.0, 1.0),
                        },
                        _ => ClusterAction::RestoreSite {
                            dc: rng.below(dcs),
                        },
                    })
                    .collect::<Vec<ClusterAction>>()
            },
            |actions| {
                let mut st = ClusterState::from_config(&cfg);
                for a in actions {
                    st.apply(a);
                }
                for l in 0..dcs {
                    for (ti, &n) in st.nodes(l).iter().enumerate() {
                        // `frac.round()` may round 0.5 up: allow equality
                        // with baseline but never growth
                        if n > baseline.nodes(l)[ti] {
                            return Err(format!(
                                "site {l} type {ti}: {n} > baseline {}",
                                baseline.nodes(l)[ti]
                            ));
                        }
                    }
                    if st.total_nodes(l) > baseline.total_nodes(l) {
                        return Err(format!("site {l} grew"));
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn prop_dyn_panels_always_match_live_counts() {
        let cfg = SystemConfig::small_test();
        let dcs = cfg.datacenters.len();
        let signals = GridSignals::generate(&cfg, 4, 1);
        let trace = Trace::generate(&cfg, 4, 1);
        crate::util::propkit::check(
            "panels-match-live-counts",
            0xC3,
            64, // each case builds full panels; keep the budget modest
            |rng| {
                (0..rng.below(8))
                    .map(|_| gen_action(rng, dcs))
                    .collect::<Vec<ClusterAction>>()
            },
            |actions| {
                let mut st = ClusterState::from_config(&cfg);
                for a in actions {
                    st.apply(a);
                }
                let (cp, dp) = build_panels_dyn(
                    &cfg,
                    &st,
                    &signals,
                    2,
                    &trace.epochs[2],
                    0.05,
                );
                for l in 0..dcs {
                    let want = st.total_nodes(l) as f64;
                    if dp.nodes[l] != want {
                        return Err(format!(
                            "dp.nodes[{l}] = {} but live total is {want}",
                            dp.nodes[l]
                        ));
                    }
                }
                // panel shapes and positivity survive arbitrary topology
                if cp.thr.len() != cp.classes * cp.dcs {
                    return Err("thr shape".into());
                }
                if !cp.thr.iter().all(|&t| t > 0.0) {
                    return Err("non-positive throughput".into());
                }
                if !cp.proc.iter().all(|&p| p > 0.0) {
                    return Err("non-positive proc time".into());
                }
                Ok(())
            },
        );
    }

    #[test]
    fn capacity_commit_and_utilization() {
        let cfg = SystemConfig::small_test();
        let mut cap = DcCapacity::new(&cfg.datacenters[0], 900.0);
        // type 0 has 10 nodes -> 9000 node-seconds
        assert!(cap.commit(0, 4500.0));
        assert!((cap.utilization(0) - 0.5).abs() < 1e-12);
        assert!(cap.commit(0, 4500.0));
        assert!(!cap.commit(0, 1.0));
        assert_eq!(cap.remaining_s(0), 0.0);
        assert!((cap.on_nodes(0, 900.0) - 10.0).abs() < 1e-12);
        assert!(cap.site_utilization() > 0.0 && cap.site_utilization() <= 1.0);
    }
}
