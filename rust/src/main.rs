//! `slit` binary: the leader entrypoint. See `slit help` / cli.rs.

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = slit::cli::run(&argv) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}
