//! Workload arrival predictor (§5.1): a *set* of incrementally-trained
//! linear (ridge) regressors over the epoch history, with `best_fit`
//! selecting the member with the lowest recent validation error — the
//! regression-predictor design of [28] adapted to LLM epochs.
//!
//! Feature vector per epoch t (matches python/compile/shapes.py):
//!   [1, lag1, lag2, lag3, lag4, sin(2*pi*t/96), cos(2*pi*t/96), lag96]
//! Lags are normalised by a running mean so coefficients stay O(1).
//!
//! The same fit also ships as an AOT HLO artifact (predictor.hlo.txt);
//! `runtime::Engine` can execute it instead of the native path — both are
//! parity-tested in rust/tests/.

use std::collections::VecDeque;

use crate::config::{SystemConfig, CLASSES};
use crate::trace::{ClassLoad, EpochLoad};

/// Feature count (keep in sync with python/compile/shapes.py F).
pub const FEATURES: usize = 8;
/// History window (shapes.H).
pub const WINDOW: usize = 192;
/// Ridge lambdas tried per fit (shapes.D) — the "predictor set".
pub const LAMBDAS: [f64; 4] = [0.01, 0.1, 1.0, 10.0];

/// Build the feature vector for predicting epoch `t` of series `y`
/// (y[t-1], y[t-2], ... are available). Values are scaled by `scale`.
pub fn features(y: &[f64], t: usize, scale: f64, epochs_per_day: usize) -> [f64; FEATURES] {
    let lag = |d: usize| -> f64 {
        if t >= d {
            y[t - d] / scale
        } else {
            1.0
        }
    };
    let phase = 2.0 * std::f64::consts::PI * (t % epochs_per_day) as f64
        / epochs_per_day as f64;
    [
        1.0,
        lag(1),
        lag(2),
        lag(3),
        lag(4),
        phase.sin(),
        phase.cos(),
        lag(epochs_per_day),
    ]
}

/// Solve (A + lam*I) x = b by Gaussian elimination with partial pivoting.
/// A is FEATURES x FEATURES row-major; used for the ridge normal equations.
pub fn solve_ridge(a: &[f64], b: &[f64], lam: f64) -> Vec<f64> {
    let n = b.len();
    let mut m = vec![0.0f64; n * (n + 1)];
    for i in 0..n {
        for j in 0..n {
            m[i * (n + 1) + j] = a[i * n + j] + if i == j { lam } else { 0.0 };
        }
        m[i * (n + 1) + n] = b[i];
    }
    for col in 0..n {
        // pivot
        let mut piv = col;
        for r in col + 1..n {
            if m[r * (n + 1) + col].abs() > m[piv * (n + 1) + col].abs() {
                piv = r;
            }
        }
        if piv != col {
            for j in 0..=n {
                m.swap(col * (n + 1) + j, piv * (n + 1) + j);
            }
        }
        let d = m[col * (n + 1) + col];
        if d.abs() < 1e-12 {
            continue; // singular direction; ridge term normally prevents this
        }
        for r in 0..n {
            if r == col {
                continue;
            }
            let f = m[r * (n + 1) + col] / d;
            for j in col..=n {
                m[r * (n + 1) + j] -= f * m[col * (n + 1) + j];
            }
        }
    }
    (0..n)
        .map(|i| {
            let d = m[i * (n + 1) + i];
            if d.abs() < 1e-12 {
                0.0
            } else {
                m[i * (n + 1) + n] / d
            }
        })
        .collect()
}

/// One ridge fit over a window: returns (beta, train_rmse).
pub fn fit_window(
    xs: &[[f64; FEATURES]],
    ys: &[f64],
    lam: f64,
) -> (Vec<f64>, f64) {
    let n = xs.len();
    let mut xtx = vec![0.0f64; FEATURES * FEATURES];
    let mut xty = vec![0.0f64; FEATURES];
    for (x, &y) in xs.iter().zip(ys) {
        for i in 0..FEATURES {
            xty[i] += x[i] * y;
            for j in 0..FEATURES {
                xtx[i * FEATURES + j] += x[i] * x[j];
            }
        }
    }
    let beta = solve_ridge(&xtx, &xty, lam);
    let mut sse = 0.0;
    for (x, &y) in xs.iter().zip(ys) {
        let pred: f64 = x.iter().zip(&beta).map(|(a, b)| a * b).sum();
        sse += (pred - y) * (pred - y);
    }
    (beta, (sse / n.max(1) as f64).sqrt())
}

/// The predictor set for one scalar series with `best_fit` selection.
#[derive(Clone, Debug)]
pub struct SeriesPredictor {
    history: VecDeque<f64>,
    epochs_seen: usize,
    epochs_per_day: usize,
    /// rolling validation error per lambda (EWMA of one-step-ahead error)
    val_err: [f64; LAMBDAS.len()],
    betas: [Option<Vec<f64>>; LAMBDAS.len()],
    scale: f64,
}

impl SeriesPredictor {
    pub fn new(epochs_per_day: usize) -> Self {
        SeriesPredictor {
            history: VecDeque::with_capacity(WINDOW + 1),
            epochs_seen: 0,
            epochs_per_day,
            val_err: [0.0; LAMBDAS.len()],
            betas: [const { None }; LAMBDAS.len()],
            scale: 1.0,
        }
    }

    /// Record the realised value for the epoch just finished; incrementally
    /// refit the set (line 1 of Algorithm 1 keeps the set trained).
    pub fn observe(&mut self, value: f64) {
        // update one-step validation error of the previous predictions
        for (i, beta) in self.betas.iter().enumerate() {
            if let Some(beta) = beta {
                let y: Vec<f64> = self.history.iter().copied().collect();
                let x = features(&y, y.len(), self.scale, self.epochs_per_day);
                let pred: f64 =
                    x.iter().zip(beta).map(|(a, b)| a * b).sum::<f64>()
                        * self.scale;
                let err = (pred - value).abs();
                self.val_err[i] = 0.8 * self.val_err[i] + 0.2 * err;
            }
        }

        self.history.push_back(value);
        if self.history.len() > WINDOW {
            self.history.pop_front();
        }
        self.epochs_seen += 1;

        // refit on the window
        let y: Vec<f64> = self.history.iter().copied().collect();
        if y.len() < 8 {
            return;
        }
        self.scale = (y.iter().sum::<f64>() / y.len() as f64).max(1.0);
        let mut xs = Vec::with_capacity(y.len());
        let mut ys = Vec::with_capacity(y.len());
        for t in 5..y.len() {
            xs.push(features(&y, t, self.scale, self.epochs_per_day));
            ys.push(y[t] / self.scale);
        }
        for (i, &lam) in LAMBDAS.iter().enumerate() {
            let (beta, _) = fit_window(&xs, &ys, lam);
            self.betas[i] = Some(beta);
        }
    }

    /// `best_fit` member index (lowest rolling validation error).
    pub fn best_fit(&self) -> usize {
        self.val_err
            .iter()
            .enumerate()
            .min_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .map(|(i, _)| i)
            .unwrap_or(0)
    }

    /// Predict the next epoch's value (>= 0). Falls back to the last value
    /// (or 0) until enough history exists.
    pub fn predict(&self) -> f64 {
        let y: Vec<f64> = self.history.iter().copied().collect();
        if let Some(beta) = &self.betas[self.best_fit()] {
            let x = features(&y, y.len(), self.scale, self.epochs_per_day);
            let pred: f64 =
                x.iter().zip(beta).map(|(a, b)| a * b).sum::<f64>() * self.scale;
            pred.max(0.0)
        } else {
            y.last().copied().unwrap_or(0.0)
        }
    }

    pub fn history_len(&self) -> usize {
        self.history.len()
    }
}

/// Per-class workload predictor producing the EpochLoad the scheduler
/// plans against.
#[derive(Clone, Debug)]
pub struct WorkloadPredictor {
    per_class: Vec<SeriesPredictor>,
    /// EWMA of token means per class (slowly varying; no regression needed).
    tok_in: Vec<f64>,
    tok_out: Vec<f64>,
}

impl WorkloadPredictor {
    pub fn new(cfg: &SystemConfig) -> Self {
        let epd = (86_400.0 / cfg.physics.epoch_s).round() as usize;
        WorkloadPredictor {
            per_class: (0..CLASSES).map(|_| SeriesPredictor::new(epd)).collect(),
            tok_in: vec![0.0; CLASSES],
            tok_out: vec![0.0; CLASSES],
        }
    }

    pub fn observe(&mut self, load: &EpochLoad) {
        for (k, c) in load.classes.iter().enumerate() {
            self.per_class[k].observe(c.n_req);
            if c.n_req > 0.0 {
                let w = 0.3;
                self.tok_in[k] = if self.tok_in[k] == 0.0 {
                    c.tok_in
                } else {
                    (1.0 - w) * self.tok_in[k] + w * c.tok_in
                };
                self.tok_out[k] = if self.tok_out[k] == 0.0 {
                    c.tok_out
                } else {
                    (1.0 - w) * self.tok_out[k] + w * c.tok_out
                };
            }
        }
    }

    pub fn predict_next(&self) -> EpochLoad {
        EpochLoad {
            classes: (0..CLASSES)
                .map(|k| ClassLoad {
                    n_req: self.per_class[k].predict(),
                    tok_in: self.tok_in[k].max(1.0),
                    tok_out: self.tok_out[k].max(1.0),
                    ..ClassLoad::default()
                })
                .collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SystemConfig;
    use crate::trace::Trace;
    use crate::util::rng::Rng;

    #[test]
    fn ridge_solver_recovers_identity_system() {
        // A = I: solution is b / (1 + lam)
        let n = FEATURES;
        let mut a = vec![0.0; n * n];
        for i in 0..n {
            a[i * n + i] = 1.0;
        }
        let b: Vec<f64> = (0..n).map(|i| i as f64).collect();
        let x = solve_ridge(&a, &b, 0.0);
        for i in 0..n {
            assert!((x[i] - i as f64).abs() < 1e-9);
        }
        let x2 = solve_ridge(&a, &b, 1.0);
        for i in 0..n {
            assert!((x2[i] - i as f64 / 2.0).abs() < 1e-9);
        }
    }

    #[test]
    fn fit_recovers_linear_signal() {
        // y[t] = 0.5 * y[t-1] + 10 with a sinusoidal component
        let mut y = vec![20.0f64];
        for t in 1..300 {
            let s = (2.0 * std::f64::consts::PI * t as f64 / 96.0).sin();
            y.push(0.5 * y[t - 1] + 10.0 + 2.0 * s);
        }
        let scale = 20.0;
        let mut xs = Vec::new();
        let mut ys = Vec::new();
        for t in 96..y.len() {
            xs.push(features(&y, t, scale, 96));
            ys.push(y[t] / scale);
        }
        let (beta, rmse) = fit_window(&xs, &ys, 0.001);
        assert!(rmse < 0.02, "rmse {rmse}");
        assert!(!beta.iter().any(|b| b.is_nan()));
    }

    #[test]
    fn series_predictor_learns_periodic_series() {
        let mut p = SeriesPredictor::new(96);
        let series = |t: usize| -> f64 {
            1000.0
                + 400.0 * (2.0 * std::f64::consts::PI * t as f64 / 96.0).sin()
        };
        for t in 0..192 {
            p.observe(series(t));
        }
        let pred = p.predict();
        let actual = series(192);
        let rel = (pred - actual).abs() / actual;
        assert!(rel < 0.05, "pred {pred} actual {actual}");
    }

    #[test]
    fn best_fit_tracks_validation_error() {
        let mut p = SeriesPredictor::new(96);
        for t in 0..150 {
            p.observe(500.0 + 10.0 * (t as f64 * 0.7).sin());
        }
        // after observing, the best-fit member must be a valid index with
        // low rolling error relative to the series scale
        let bf = p.best_fit();
        assert!(bf < LAMBDAS.len());
        assert!(p.val_err[bf] < 100.0, "{:?}", p.val_err);
    }

    #[test]
    fn workload_predictor_tracks_trace_scale() {
        let cfg = SystemConfig::small_test();
        let trace = Trace::generate(&cfg, 96, 21);
        let mut p = WorkloadPredictor::new(&cfg);
        let mut errs = Vec::new();
        for (t, e) in trace.epochs.iter().enumerate() {
            if t > 48 {
                let pred = p.predict_next();
                let actual = e.total_requests();
                if actual > 0.0 {
                    errs.push((pred.total_requests() - actual).abs() / actual);
                }
            }
            p.observe(e);
        }
        let mape = errs.iter().sum::<f64>() / errs.len() as f64;
        // the trace is deliberately bursty; requiring < 60% MAPE checks the
        // predictor is tracking scale, not that it's clairvoyant
        assert!(mape < 0.6, "mape {mape}");
    }

    #[test]
    fn predictor_nonnegative_and_token_means_positive() {
        let cfg = SystemConfig::small_test();
        let mut p = WorkloadPredictor::new(&cfg);
        let mut rng = Rng::new(5);
        // feed noisy small loads including zeros
        for _ in 0..60 {
            let load = EpochLoad {
                classes: (0..CLASSES)
                    .map(|_| ClassLoad {
                        n_req: if rng.chance(0.3) { 0.0 } else { rng.range(0.0, 50.0) },
                        tok_in: 100.0,
                        tok_out: 200.0,
                        ..ClassLoad::default()
                    })
                    .collect(),
            };
            p.observe(&load);
        }
        let pred = p.predict_next();
        for c in &pred.classes {
            assert!(c.n_req >= 0.0);
            assert!(c.tok_in >= 1.0 && c.tok_out >= 1.0);
        }
    }
}
