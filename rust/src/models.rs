//! The paper's physical models, Eqs. 1-18, as pure scalar functions plus an
//! epoch accounting ledger.
//!
//! These are the single source of truth on the rust side: the discrete
//! simulator calls them per node/request, and `eval::AnalyticEvaluator`
//! vectorises exactly the same arithmetic (tested for parity), as does the
//! AOT HLO kernel (tested for parity in rust/tests/runtime_parity.rs).
//!
//! Units: energy J internally (kWh at the grid boundary), water liters,
//! carbon kg (CI is kg/kWh), money in $ (TOU is $/kWh), time seconds.

pub const J_PER_KWH: f64 = 3.6e6;

/// Node power states (Eq. 5).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum PState {
    On,
    Idle,
    Off,
}

/// Eq. 1 — memory footprint of request i: KV cache grows per output token
/// on top of the shared model parameter memory. GB.
pub fn memory_footprint_gb(
    out_tokens: f64,
    kv_gb_per_token: f64,
    model_mem_gb: f64,
) -> f64 {
    out_tokens * kv_gb_per_token + model_mem_gb
}

/// Eq. 2 — model loading (orchestration) overhead, s.
pub fn load_latency_s(model_mem_gb: f64, bw_gbs: f64) -> f64 {
    model_mem_gb / bw_gbs.max(1e-9)
}

/// Eq. 3 — cross-datacenter migration latency, s.
pub fn migration_latency_s(hops: f64, k_media_s: f64) -> f64 {
    hops * k_media_s
}

/// Eq. 4 — TTFT: load + 2x migration + first-token processing time, s.
/// `t_exec_s` is the total execution time, `n_tokens` the output tokens.
pub fn ttft_s(
    load_s: f64,
    migration_s: f64,
    t_exec_s: f64,
    n_tokens: f64,
) -> f64 {
    load_s + 2.0 * migration_s + t_exec_s / n_tokens.max(1.0)
}

/// Eq. 5 — node energy over an interval, J, for a power state.
pub fn node_energy_j(
    pstate: PState,
    tdp_w: f64,
    dt_s: f64,
    pr_on: f64,
    pr_idle: f64,
    pr_off: f64,
) -> f64 {
    let pr = match pstate {
        PState::On => pr_on,
        PState::Idle => pr_idle,
        PState::Off => pr_off,
    };
    pr * tdp_w * dt_s
}

/// Eq. 7 — CRAC energy from IT energy and cooling CoP, J.
pub fn crac_energy_j(e_it_j: f64, cop: f64) -> f64 {
    e_it_j / cop.max(1e-9)
}

/// Eq. 8 — total mechanical cooling energy (chillers ~ 2x CRAC on top), J.
pub fn cooling_energy_j(e_it_j: f64, cop: f64) -> f64 {
    3.0 * crac_energy_j(e_it_j, cop)
}

/// Eq. 9 — internal power-conditioning overhead, J.
pub fn support_energy_j(e_it_j: f64) -> f64 {
    0.13 * e_it_j
}

/// Eq. 10 — total site energy from IT energy, J.
pub fn total_energy_j(e_it_j: f64, cop: f64) -> f64 {
    e_it_j + cooling_energy_j(e_it_j, cop) + support_energy_j(e_it_j)
}

/// Multiplier from E_IT to E_tot (used by the vectorised evaluator).
pub fn total_energy_factor(cop: f64) -> f64 {
    1.0 + 3.0 / cop.max(1e-9) + 0.13
}

/// Eq. 11 — energy cost, $: E_tot (kWh) x TOU ($/kWh).
pub fn energy_cost(e_tot_j: f64, tou_per_kwh: f64) -> f64 {
    e_tot_j / J_PER_KWH * tou_per_kwh
}

/// Eq. 12 — evaporative water from IT heat, L. All IT energy becomes heat.
pub fn evaporative_water_l(e_it_j: f64, h_water_j_per_l: f64) -> f64 {
    e_it_j / h_water_j_per_l.max(1e-9)
}

/// Eq. 13 — blowdown water from evaporative water and solids ratio D, L.
pub fn blowdown_water_l(w_e_l: f64, d_ratio: f64) -> f64 {
    w_e_l / (1.0 - d_ratio).max(1e-9)
}

/// Eq. 14 — off-site water embedded in electricity, L.
pub fn grid_water_l(e_tot_j: f64, wi_l_per_kwh: f64) -> f64 {
    e_tot_j / J_PER_KWH * wi_l_per_kwh
}

/// Eq. 15 contribution of one site, L.
pub fn site_water_l(
    e_it_j: f64,
    e_tot_j: f64,
    h_water: f64,
    d_ratio: f64,
    wi: f64,
) -> f64 {
    let w_e = evaporative_water_l(e_it_j, h_water);
    w_e + blowdown_water_l(w_e, d_ratio) + grid_water_l(e_tot_j, wi)
}

/// Eq. 16 — grid carbon, kg: CI (kg/kWh) x E_tot (kWh).
pub fn grid_carbon_kg(e_tot_j: f64, ci_kg_per_kwh: f64) -> f64 {
    e_tot_j / J_PER_KWH * ci_kg_per_kwh
}

/// Eq. 17 — carbon from water treatment energy, kg.
pub fn water_carbon_kg(
    w_e_l: f64,
    w_b_l: f64,
    w_grid_l: f64,
    ei_pot_kwh_per_l: f64,
    ei_waste_kwh_per_l: f64,
    ci_kg_per_kwh: f64,
) -> f64 {
    ((w_e_l + w_b_l) * ei_pot_kwh_per_l + w_grid_l * ei_waste_kwh_per_l)
        * ci_kg_per_kwh
}

/// Eq. 18 contribution of one site, kg.
pub fn site_carbon_kg(
    e_it_j: f64,
    e_tot_j: f64,
    h_water: f64,
    d_ratio: f64,
    wi: f64,
    ei_pot: f64,
    ei_waste: f64,
    ci: f64,
) -> f64 {
    let w_e = evaporative_water_l(e_it_j, h_water);
    let w_b = blowdown_water_l(w_e, d_ratio);
    let w_g = grid_water_l(e_tot_j, wi);
    grid_carbon_kg(e_tot_j, ci)
        + water_carbon_kg(w_e, w_b, w_g, ei_pot, ei_waste, ci)
}

/// Accumulated sustainability + performance metrics for one epoch (or a
/// whole run — ledgers merge).
#[derive(Clone, Debug, Default)]
pub struct EpochLedger {
    pub e_it_j: f64,
    pub e_tot_j: f64,
    pub cost_usd: f64,
    pub water_l: f64,
    pub carbon_kg: f64,
    /// Sum and count of per-request TTFTs (mean = sum/count).
    pub ttft_sum_s: f64,
    pub requests: f64,
    /// Requests that could not be served this epoch.
    pub dropped: f64,
    /// Realised demand per request class (served + dropped), indexed by
    /// class id. Empty when the producer does not track classes (e.g. the
    /// serving coordinator's aggregate ledger); the per-class feedback
    /// scheduler falls back to the level-only correction in that case.
    pub class_requests: Vec<f64>,
    /// TTFT distribution for every request recorded via
    /// [`EpochLedger::add_request`] (p50/p95/p99 in the epoch CSV).
    pub ttft_hist: crate::util::histogram::LatencyHistogram,
    /// Deferrable request mass offered (enqueued) this epoch.
    pub deferred_offered: f64,
    /// Deferred mass released into this epoch's served load by the
    /// temporal-shifting layer (`opt::shift`).
    pub deferred_released: f64,
    /// Deferred mass still queued at the end of this epoch. A snapshot,
    /// not a flow: `merge` keeps the *latest* value rather than summing,
    /// so a run-total ledger reports the final queue depth.
    pub deferred_queued: f64,
    /// Deferred mass that passed its deadline unreleased. The shifting
    /// layer force-releases at the deadline, so this stays 0 for every
    /// shipped policy; the conservation tests pin that.
    pub deferred_expired: f64,
    /// Per-objective certified lower bound from the optimality-gap
    /// oracle (`opt::oracle`), [ttft, carbon, water, cost]. Sums across
    /// merges (the bound on a run is the sum of per-epoch bounds, since
    /// epochs are independent placement problems). 0 when the producer
    /// does not run the oracle (serving coordinator).
    pub oracle_lb: [f64; 4],
    /// The framework plan's analytic score on each objective for the
    /// same epochs — the oracle's comparison side. Analytic, not the
    /// sampled discrete ledger: soundness (lb <= achieved) then holds
    /// deterministically, free of warm/cold sampling noise.
    pub oracle_achieved: [f64; 4],
    /// Summed quantization slack the bounds already concede.
    pub oracle_slack: [f64; 4],
    /// Sites whose grid-telemetry feed was Fresh / Stale / Quarantined
    /// this epoch (`signals::SignalFeed::health_counts`). Sum across
    /// merges, so a run total reads in site-epochs. 0 when the producer
    /// has no signal feed.
    pub signal_fresh: f64,
    pub signal_stale: f64,
    pub signal_quarantined: f64,
    /// Sum over sites of |believed − truth| for the signal view the
    /// framework actually consumed, per axis [ci, wue, tou]. Exactly 0
    /// when no faults are injected (rust/tests/signal_faults.rs pins
    /// it); under faults this is the measured telemetry error the
    /// scheduler planned on.
    pub signal_div: [f64; 3],
}

impl EpochLedger {
    pub fn add_site(
        &mut self,
        e_it_j: f64,
        cop: f64,
        tou: f64,
        h_water: f64,
        d_ratio: f64,
        wi: f64,
        ei_pot: f64,
        ei_waste: f64,
        ci: f64,
    ) {
        let e_tot = total_energy_j(e_it_j, cop);
        self.e_it_j += e_it_j;
        self.e_tot_j += e_tot;
        self.cost_usd += energy_cost(e_tot, tou);
        self.water_l += site_water_l(e_it_j, e_tot, h_water, d_ratio, wi);
        self.carbon_kg +=
            site_carbon_kg(e_it_j, e_tot, h_water, d_ratio, wi, ei_pot, ei_waste, ci);
    }

    pub fn add_request(&mut self, ttft_s: f64) {
        self.ttft_sum_s += ttft_s;
        self.requests += 1.0;
        self.ttft_hist.record(ttft_s);
    }

    pub fn mean_ttft_s(&self) -> f64 {
        if self.requests > 0.0 {
            self.ttft_sum_s / self.requests
        } else {
            0.0
        }
    }

    pub fn merge(&mut self, other: &EpochLedger) {
        self.e_it_j += other.e_it_j;
        self.e_tot_j += other.e_tot_j;
        self.cost_usd += other.cost_usd;
        self.water_l += other.water_l;
        self.carbon_kg += other.carbon_kg;
        self.ttft_sum_s += other.ttft_sum_s;
        self.requests += other.requests;
        self.dropped += other.dropped;
        if self.class_requests.len() < other.class_requests.len() {
            self.class_requests.resize(other.class_requests.len(), 0.0);
        }
        for (a, b) in self.class_requests.iter_mut().zip(&other.class_requests)
        {
            *a += b;
        }
        self.ttft_hist.merge(&other.ttft_hist);
        self.deferred_offered += other.deferred_offered;
        self.deferred_released += other.deferred_released;
        self.deferred_expired += other.deferred_expired;
        for i in 0..4 {
            self.oracle_lb[i] += other.oracle_lb[i];
            self.oracle_achieved[i] += other.oracle_achieved[i];
            self.oracle_slack[i] += other.oracle_slack[i];
        }
        self.signal_fresh += other.signal_fresh;
        self.signal_stale += other.signal_stale;
        self.signal_quarantined += other.signal_quarantined;
        for i in 0..3 {
            self.signal_div[i] += other.signal_div[i];
        }
        // queue depth is a snapshot: keep the most recent one
        self.deferred_queued = other.deferred_queued;
    }

    /// Objective vector [ttft, carbon, water, cost] (paper's four axes).
    pub fn objectives(&self) -> [f64; 4] {
        [
            self.mean_ttft_s(),
            self.carbon_kg,
            self.water_l,
            self.cost_usd,
        ]
    }

    /// Optimality gap on objective `obj` vs the accumulated oracle lower
    /// bound: `(achieved - lb) / |achieved|`. 0 = provably optimal; 1 =
    /// the oracle certifies nothing beyond nonnegativity. Uses the
    /// analytic achieved side recorded next to the bound, so soundness
    /// (result >= 0) is deterministic.
    pub fn oracle_gap_frac(&self, obj: usize) -> f64 {
        let a = self.oracle_achieved[obj];
        (a - self.oracle_lb[obj]) / a.abs().max(1e-12)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn eq1_memory_footprint() {
        // 200 output tokens of 70B KV + params
        let m = memory_footprint_gb(200.0, 0.0025, 140.0);
        assert!((m - 140.5).abs() < 1e-12);
    }

    #[test]
    fn eq2_load_latency() {
        assert!((load_latency_s(140.0, 14.0) - 10.0).abs() < 1e-12);
    }

    #[test]
    fn eq4_ttft_combines_terms() {
        let t = ttft_s(1.0, 0.02, 10.0, 100.0);
        assert!((t - (1.0 + 0.04 + 0.1)).abs() < 1e-12);
    }

    #[test]
    fn eq5_pstates_ordered() {
        let on = node_energy_j(PState::On, 1000.0, 900.0, 1.0, 0.3, 0.05);
        let idle = node_energy_j(PState::Idle, 1000.0, 900.0, 1.0, 0.3, 0.05);
        let off = node_energy_j(PState::Off, 1000.0, 900.0, 1.0, 0.3, 0.05);
        assert!(on > idle && idle > off);
        assert!((on - 900_000.0).abs() < 1e-9);
        assert!((idle - 270_000.0).abs() < 1e-9);
    }

    #[test]
    fn eq7_to_10_energy_chain() {
        let e_it = 1000.0;
        let cop = 4.0;
        assert!((crac_energy_j(e_it, cop) - 250.0).abs() < 1e-12);
        assert!((cooling_energy_j(e_it, cop) - 750.0).abs() < 1e-12);
        assert!((support_energy_j(e_it) - 130.0).abs() < 1e-12);
        let tot = total_energy_j(e_it, cop);
        assert!((tot - 1880.0).abs() < 1e-12);
        assert!((total_energy_factor(cop) - 1.88).abs() < 1e-12);
    }

    #[test]
    fn eq11_cost() {
        // 1 kWh at $0.10
        assert!((energy_cost(J_PER_KWH, 0.1) - 0.1).abs() < 1e-12);
    }

    #[test]
    fn eq12_13_water_chain() {
        let w_e = evaporative_water_l(2.45e6, 2.45e6);
        assert!((w_e - 1.0).abs() < 1e-12);
        let w_b = blowdown_water_l(w_e, 0.3);
        assert!((w_b - 1.0 / 0.7).abs() < 1e-12);
    }

    #[test]
    fn eq14_grid_water() {
        assert!((grid_water_l(J_PER_KWH, 3.0) - 3.0).abs() < 1e-12);
    }

    #[test]
    fn eq16_18_carbon() {
        let c = grid_carbon_kg(J_PER_KWH, 0.5);
        assert!((c - 0.5).abs() < 1e-12);
        let cw = water_carbon_kg(1.0, 1.0, 2.0, 0.003, 0.0015, 0.5);
        assert!((cw - (2.0 * 0.003 + 2.0 * 0.0015) * 0.5).abs() < 1e-12);
        let site = site_carbon_kg(
            J_PER_KWH, J_PER_KWH, 2.45e6, 0.3, 3.0, 0.003, 0.0015, 0.5,
        );
        assert!(site > c);
    }

    #[test]
    fn ledger_accumulates_and_merges() {
        let mut a = EpochLedger::default();
        a.add_site(J_PER_KWH, 4.0, 0.1, 2.45e6, 0.3, 2.0, 0.003, 0.0015, 0.4);
        a.add_request(0.5);
        a.add_request(1.5);
        assert!((a.mean_ttft_s() - 1.0).abs() < 1e-12);
        assert!(a.carbon_kg > 0.0 && a.water_l > 0.0 && a.cost_usd > 0.0);

        let mut b = EpochLedger::default();
        b.add_request(3.0);
        b.merge(&a);
        assert_eq!(b.requests, 3.0);
        assert!((b.mean_ttft_s() - 5.0 / 3.0).abs() < 1e-12);
        assert_eq!(b.carbon_kg, a.carbon_kg);
    }

    #[test]
    fn ledger_merges_class_requests_with_mixed_arity() {
        let mut a = EpochLedger {
            class_requests: vec![1.0, 2.0],
            ..Default::default()
        };
        let b = EpochLedger {
            class_requests: vec![10.0, 20.0, 30.0],
            ..Default::default()
        };
        a.merge(&b);
        assert_eq!(a.class_requests, vec![11.0, 22.0, 30.0]);
        // merging a class-less ledger leaves the counts untouched
        a.merge(&EpochLedger::default());
        assert_eq!(a.class_requests, vec![11.0, 22.0, 30.0]);
    }

    #[test]
    fn objectives_layout_matches_config() {
        let mut l = EpochLedger::default();
        l.add_site(1e6, 4.0, 0.1, 2.45e6, 0.3, 2.0, 0.003, 0.0015, 0.4);
        l.add_request(0.25);
        let o = l.objectives();
        assert_eq!(o[crate::config::OBJ_TTFT], l.mean_ttft_s());
        assert_eq!(o[crate::config::OBJ_CARBON], l.carbon_kg);
        assert_eq!(o[crate::config::OBJ_WATER], l.water_l);
        assert_eq!(o[crate::config::OBJ_COST], l.cost_usd);
    }

    #[test]
    fn more_it_energy_more_everything() {
        let mk = |e: f64| {
            let mut l = EpochLedger::default();
            l.add_site(e, 4.0, 0.1, 2.45e6, 0.3, 2.0, 0.003, 0.0015, 0.4);
            l
        };
        let lo = mk(1e6);
        let hi = mk(2e6);
        assert!(hi.carbon_kg > lo.carbon_kg);
        assert!(hi.water_l > lo.water_l);
        assert!(hi.cost_usd > lo.cost_usd);
    }
}
