"""aot.py end-to-end CLI: writes all artifacts + manifest to --out-dir."""

import json
import subprocess
import sys


def test_aot_main_writes_artifacts(tmp_path):
    out = tmp_path / "artifacts"
    res = subprocess.run(
        [sys.executable, "-m", "compile.aot", "--out-dir", str(out)],
        capture_output=True,
        text=True,
        cwd=".",
    )
    assert res.returncode == 0, res.stderr
    for name in ("plan_eval.hlo.txt", "predictor.hlo.txt", "manifest.json"):
        assert (out / name).exists(), name
    man = json.loads((out / "manifest.json").read_text())
    assert "sha256" in man["plan_eval"]
    assert "sha256" in man["predictor"]
    # HLO text is parseable-looking and non-trivial
    text = (out / "plan_eval.hlo.txt").read_text()
    assert "ENTRY" in text and len(text) > 5_000
