"""Pallas plan-eval kernel vs the pure-jnp oracle — the core L1 signal.

hypothesis sweeps population sizes / tile sizes / DC counts and random
physical parameters; dedicated cases pin the edge regimes (zero load,
saturation, single-DC routing).
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import shapes
from compile.kernels.plan_eval import plan_eval
from compile.kernels.ref import plan_eval_ref
from tests.gen import make_inputs

RTOL = 2e-5
ATOL = 1e-6


def assert_matches(inputs, tp=shapes.TP):
    got = np.asarray(plan_eval(*[np.asarray(x) for x in inputs], tp=tp))
    want = np.asarray(plan_eval_ref(*inputs))
    np.testing.assert_allclose(got, want, rtol=RTOL, atol=ATOL)
    assert got.shape == (inputs[0].shape[0], shapes.N_OBJ)
    assert np.all(np.isfinite(got))


def test_default_shapes_match_ref():
    rng = np.random.default_rng(0)
    assert_matches(make_inputs(rng))


@settings(max_examples=25, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    tiles=st.integers(1, 6),
    tp=st.sampled_from([4, 8, 16]),
    real_l=st.integers(1, 12),
)
def test_shape_sweep_matches_ref(seed, tiles, tp, real_l):
    rng = np.random.default_rng(seed)
    inputs = make_inputs(rng, p=tiles * tp, real_l=real_l)
    assert_matches(inputs, tp=tp)


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), scale=st.floats(0.0, 1e3))
def test_load_scaling_is_finite_and_monotone_energy(seed, scale):
    """Scaling request counts up never *reduces* any objective."""
    rng = np.random.default_rng(seed)
    a, cls, thr, proc, hops, dc, consts = make_inputs(rng, p=shapes.TP)
    lo = np.asarray(plan_eval_ref(a, cls, thr, proc, hops, dc, consts))
    cls_hi = cls.copy()
    cls_hi[:, 0] *= 1.0 + scale
    hi = np.asarray(plan_eval_ref(a, cls_hi, thr, proc, hops, dc, consts))
    # carbon / water / cost are monotone in load (columns 1..3)
    assert np.all(hi[:, 1:] >= lo[:, 1:] - 1e-6)


def test_zero_load_gives_idle_floor_only():
    rng = np.random.default_rng(1)
    a, cls, thr, proc, hops, dc, consts = make_inputs(rng, p=shapes.TP)
    cls[:, 0] = 0.0
    out = np.asarray(plan_eval(a, cls, thr, proc, hops, dc, consts))
    want = np.asarray(plan_eval_ref(a, cls, thr, proc, hops, dc, consts))
    np.testing.assert_allclose(out, want, rtol=RTOL, atol=ATOL)
    # no requests -> no TTFT, but idle nodes still burn energy/water/carbon
    assert np.allclose(out[:, 0], 0.0, atol=1e-6)
    assert np.all(out[:, 1:] > 0.0)


def test_saturation_clamps_on_nodes():
    """Demand far beyond capacity: ON nodes clamp at the node count."""
    rng = np.random.default_rng(2)
    a, cls, thr, proc, hops, dc, consts = make_inputs(rng, p=shapes.TP)
    cls[:, 0] = 1e9
    out = np.asarray(plan_eval(a, cls, thr, proc, hops, dc, consts))
    want = np.asarray(plan_eval_ref(a, cls, thr, proc, hops, dc, consts))
    np.testing.assert_allclose(out, want, rtol=RTOL, atol=ATOL)
    assert np.all(np.isfinite(out))


def test_single_dc_routing_matches_ref():
    """Extreme plan: everything to one DC (one of SLIT's seeded extremes)."""
    rng = np.random.default_rng(3)
    a, cls, thr, proc, hops, dc, consts = make_inputs(rng, p=shapes.TP)
    a[:] = 0.0
    a[:, :, 3] = 1.0
    assert_matches((a, cls, thr, proc, hops, dc, consts))


def test_population_rows_are_independent():
    """Evaluating a plan alone or inside a batch gives identical rows."""
    rng = np.random.default_rng(4)
    inputs = make_inputs(rng, p=2 * shapes.TP)
    full = np.asarray(plan_eval(*inputs))
    a = inputs[0]
    half = np.asarray(plan_eval(a[: shapes.TP], *inputs[1:]))
    np.testing.assert_allclose(full[: shapes.TP], half, rtol=1e-6, atol=1e-7)


def test_tile_size_does_not_change_results():
    rng = np.random.default_rng(5)
    inputs = make_inputs(rng, p=32)
    a4 = np.asarray(plan_eval(*inputs, tp=4))
    a16 = np.asarray(plan_eval(*inputs, tp=16))
    np.testing.assert_allclose(a4, a16, rtol=1e-6, atol=1e-7)


def test_non_divisible_population_rejected():
    rng = np.random.default_rng(6)
    inputs = make_inputs(rng, p=shapes.TP)
    with pytest.raises(AssertionError):
        plan_eval(inputs[0][:5], *inputs[1:], tp=4)
