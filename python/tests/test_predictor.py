"""Workload-predictor graph (CG ridge) vs the exact-solve oracle."""

import numpy as np
from hypothesis import given, settings, strategies as st

from compile import shapes
from compile.kernels.ref import predictor_ref
from compile.model import predictor_model
from tests.gen import make_predictor_inputs


def test_predictor_matches_exact_solve():
    rng = np.random.default_rng(0)
    x, y, xq, lam = make_predictor_inputs(rng)
    preds, rmse = predictor_model(x, y, xq, lam)
    want_p, want_r = predictor_ref(x, y, xq, lam)
    np.testing.assert_allclose(np.asarray(preds), np.asarray(want_p),
                               rtol=1e-3, atol=1e-3)
    np.testing.assert_allclose(np.asarray(rmse), np.asarray(want_r),
                               rtol=1e-3, atol=1e-3)


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_predictor_sweep(seed):
    rng = np.random.default_rng(seed)
    x, y, xq, lam = make_predictor_inputs(rng)
    preds, rmse = predictor_model(x, y, xq, lam)
    want_p, want_r = predictor_ref(x, y, xq, lam)
    np.testing.assert_allclose(np.asarray(preds), np.asarray(want_p),
                               rtol=5e-3, atol=5e-3)
    np.testing.assert_allclose(np.asarray(rmse), np.asarray(want_r),
                               rtol=5e-3, atol=5e-3)
    assert np.all(np.isfinite(np.asarray(preds)))


def test_rmse_increases_with_lambda_on_noiseless_data():
    """With clean targets, heavier regularisation can only fit worse."""
    rng = np.random.default_rng(7)
    x, _, xq, lam = make_predictor_inputs(rng)
    beta = rng.normal(0.0, 1.0, size=shapes.F).astype(np.float32)
    y = (x @ beta).astype(np.float32)
    _, rmse = predictor_model(x, y, xq, lam)
    r = np.asarray(rmse)
    assert np.all(np.diff(r) >= -1e-4), r


def test_best_fit_prefers_small_lambda_on_clean_signal():
    rng = np.random.default_rng(8)
    x, _, xq, lam = make_predictor_inputs(rng)
    beta = rng.normal(0.0, 1.0, size=shapes.F).astype(np.float32)
    y = (x @ beta).astype(np.float32)
    _, rmse = predictor_model(x, y, xq, lam)
    assert int(np.argmin(np.asarray(rmse))) == 0
