"""AOT pipeline: lowering produces loadable HLO text + a consistent manifest.

The deep numeric check (rust PJRT executes the artifact and matches the rust
mirror evaluator) lives in rust/tests/; here we check the HLO text is
well-formed, executable by the local XLA client, and matches the oracle.
"""

import json

import numpy as np
from jax._src.lib import xla_client as xc

from compile import aot, shapes
from compile.kernels.ref import plan_eval_ref, predictor_ref
from tests.gen import make_inputs, make_predictor_inputs


def _run_hlo(text, args):
    """Round-trip the HLO text (parse -> XlaComputation -> execute).

    This mirrors what the rust runtime does with HloModuleProto::from_text:
    if the text does not parse or compile here, rust will not load it either.
    """
    client = xc.make_cpu_client()
    mod = xc._xla.hlo_module_from_text(text)
    comp = xc.XlaComputation(mod.as_serialized_hlo_module_proto())
    mlir = xc._xla.mlir.xla_computation_to_mlir_module(comp)
    exe = client.compile_and_load(mlir, client.devices())
    bufs = [client.buffer_from_pyval(np.asarray(a)) for a in args]
    out = exe.execute(bufs)
    return [np.asarray(o) for o in out]


def test_plan_eval_hlo_text_is_wellformed():
    text = aot.lower_plan_eval()
    assert "ENTRY" in text
    assert text.count("parameter(") >= 7
    # interpret=True must have erased pallas custom-calls
    assert "custom-call" not in text.lower() or "mosaic" not in text.lower()


def test_predictor_hlo_text_is_wellformed():
    text = aot.lower_predictor()
    assert "ENTRY" in text
    assert text.count("parameter(") >= 4


def test_plan_eval_hlo_executes_and_matches_oracle():
    text = aot.lower_plan_eval()
    rng = np.random.default_rng(11)
    inputs = make_inputs(rng)
    outs = _run_hlo(text, inputs)
    got = outs[0]
    want = np.asarray(plan_eval_ref(*inputs))
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=1e-6)


def test_predictor_hlo_executes_and_matches_oracle():
    text = aot.lower_predictor()
    rng = np.random.default_rng(12)
    x, y, xq, lam = make_predictor_inputs(rng)
    outs = _run_hlo(text, (x, y, xq, lam))
    want_p, want_r = predictor_ref(x, y, xq, lam)
    np.testing.assert_allclose(outs[0], np.asarray(want_p), rtol=5e-3,
                               atol=5e-3)
    np.testing.assert_allclose(outs[1], np.asarray(want_r), rtol=5e-3,
                               atol=5e-3)


def test_manifest_layout(tmp_path):
    man = aot.manifest()
    assert man["plan_eval"]["population"] == shapes.P
    assert man["plan_eval"]["dc_slots"] == shapes.L
    assert man["plan_eval"]["classes"] == shapes.K
    assert tuple(man["plan_eval"]["dc_rows"]) == shapes.DC_ROWS
    assert man["predictor"]["features"] == shapes.F
    # round-trips through json
    assert json.loads(json.dumps(man)) == man
