"""Shared random-input generators for the python test suite.

Values are drawn from physically plausible ranges (the same ranges the rust
config defaults use) so the oracle comparison exercises the regime the
scheduler actually runs in, not just abstract floats.
"""

import numpy as np

from compile import shapes


def make_inputs(rng, p=shapes.P, k=shapes.K, l=shapes.L, real_l=12,
                dtype=np.float32):
    """Random (a, cls, thr, proc, hops, dc, consts) with padded DC slots."""
    # row-stochastic plans over the real DCs only
    a = rng.gamma(0.5, 1.0, size=(p, k, l)).astype(dtype)
    a[:, :, real_l:] = 0.0
    a /= np.maximum(a.sum(axis=2, keepdims=True), 1e-12)

    cls = np.stack([
        rng.uniform(0.0, 5e4, size=k),      # n_req
        rng.uniform(16.0, 1024.0, size=k),  # tok_out
        rng.uniform(14.0, 140.0, size=k),   # model_mem GB
    ], axis=1).astype(dtype)

    thr = rng.uniform(50.0, 4000.0, size=(k, l)).astype(dtype)
    proc = rng.uniform(0.005, 0.4, size=(k, l)).astype(dtype)
    hops = rng.integers(0, 12, size=(k, l)).astype(dtype)

    dc = np.zeros((8, l), dtype=dtype)
    dc[0] = rng.integers(100, 1000, size=l)     # nodes
    dc[1] = rng.uniform(1500.0, 6000.0, size=l)  # tdp W
    dc[2] = rng.uniform(2.0, 8.0, size=l)        # cop
    dc[3] = rng.uniform(0.04, 0.45, size=l)      # tou $/kWh
    dc[4] = rng.uniform(0.02, 0.8, size=l)       # ci kg/kWh
    dc[5] = rng.uniform(0.2, 67.0, size=l)       # wi L/kWh
    dc[6] = rng.uniform(1.0, 25.0, size=l)       # bw GB/s
    dc[7] = rng.uniform(0.01, 0.35, size=l)      # unused_pr
    # padded slots: zero demand-side params, safe divisors
    dc[0, real_l:] = 0.0
    dc[2, real_l:] = 1.0
    dc[6, real_l:] = 1.0
    thr[:, real_l:] = 1.0

    consts = np.array([
        900.0,    # epoch_s
        1.0,      # pr_on
        2.45e6,   # h_water J/L (latent heat of vaporisation per liter)
        0.3,      # d_ratio
        0.003,    # ei_pot kWh/L
        0.0015,   # ei_waste kWh/L
        0.002,    # k_media s/hop
        0.25,     # q_coef s
        0.995,    # u_max
        0.1,      # cold_frac
        0.0, 0.0,
    ], dtype=dtype)

    return a, cls, thr, proc, hops, dc, consts


def make_predictor_inputs(rng, h=shapes.H, f=shapes.F, d=shapes.D,
                          dtype=np.float32):
    x = rng.normal(0.0, 1.0, size=(h, f)).astype(dtype)
    x[:, 0] = 1.0
    beta = rng.normal(0.0, 2.0, size=f).astype(dtype)
    y = (x @ beta + rng.normal(0.0, 0.1, size=h)).astype(dtype)
    xq = rng.normal(0.0, 1.0, size=f).astype(dtype)
    xq[0] = 1.0
    lambdas = np.array([0.01, 0.1, 1.0, 10.0][:d], dtype=dtype)
    return x, y, xq, lambdas
