"""L2 graph: the jax functions that get AOT-lowered for the rust runtime.

Two entry points:

* ``plan_eval_model`` — the metaheuristic hot path.  Wraps the L1 Pallas
  kernel (kernels/plan_eval.py) so the kernel lowers into the same HLO
  module the rust PJRT client executes.

* ``predictor_model`` — the workload predictor: D ridge regressions over a
  sliding window of epoch arrival counts, solved with a fixed number of
  conjugate-gradient steps (pure dense HLO — no LAPACK custom-calls, which
  the rust CPU client could not resolve), returning per-lambda predictions
  and training RMSE so the rust ``best_fit`` step can pick the winner.

Both return tuples because aot.py lowers with ``return_tuple=True`` and the
rust side unwraps with ``to_tuple1``/``to_tuple2``.
"""

import jax.numpy as jnp

from compile import shapes
from compile.kernels.plan_eval import plan_eval


def plan_eval_model(a, cls, thr, proc, hops, dc, consts):
    """obj[P, 4] = f(plans, class params, dc params).  See kernels/ref.py."""
    return (plan_eval(a, cls, thr, proc, hops, dc, consts),)


def _cg_solve(mat, rhs, iters):
    """Conjugate gradients on an SPD system, fixed iteration count.

    Ridge normal equations (XtX + lam*I) are SPD for lam > 0; F is tiny
    (shapes.F = 8) so `iters` >= F converges to machine precision in exact
    arithmetic.  Unrolled python loop -> straight-line HLO.
    """
    x = jnp.zeros_like(rhs)
    r = rhs
    p = r
    rs = jnp.dot(r, r)
    for _ in range(iters):
        mp = mat @ p
        alpha = rs / jnp.maximum(jnp.dot(p, mp), 1e-30)
        x = x + alpha * p
        r = r - alpha * mp
        rs_new = jnp.dot(r, r)
        beta = rs_new / jnp.maximum(rs, 1e-30)
        p = r + beta * p
        rs = rs_new
    return x


def predictor_model(x, y, xq, lambdas):
    """(preds[D], rmse[D]) — ridge fit per lambda, CG-solved.

    x f32[H, F] design matrix, y f32[H] targets, xq f32[F] query features.
    """
    h = x.shape[0]
    xtx = x.T @ x
    xty = x.T @ y
    eye = jnp.eye(x.shape[1], dtype=x.dtype)

    preds = []
    rmses = []
    for i in range(shapes.D):
        beta = _cg_solve(xtx + lambdas[i] * eye, xty, shapes.CG_ITERS)
        resid = x @ beta - y
        rmses.append(jnp.sqrt(jnp.sum(resid * resid) / h))
        preds.append(jnp.dot(xq, beta))
    return jnp.stack(preds), jnp.stack(rmses)
