"""L1 Pallas kernel: batched scheduling-plan evaluator.

One grid step evaluates a tile of TP plans against the full physical model
chain (Eqs. 1-18).  The per-tile working set is

    A tile        TP x K x L x 4B   (= 4 KiB at TP=8, K=8, L=16)
    param panels  (K x L) x 3 + (8 x L) + vectors   (< 3 KiB)
    accumulators  TP x L, TP x 4

i.e. well under VMEM even at TP=128; HBM traffic is one read of the plan
tensor and one write of obj[P, 4].  The class contraction (K = 8) is a
VPU multiply-reduce — at K=8 an MXU dot would run at <7% occupancy, so the
MXU-friendly axis here is the P tiling, not the contraction (see
DESIGN.md "Hardware adaptation").

interpret=True is mandatory: the CPU PJRT plugin cannot execute Mosaic
custom-calls, and the AOT path (aot.py) inlines the interpreted kernel into
plain HLO the rust runtime can compile.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from compile import shapes

J_PER_KWH = 3.6e6


def _plan_eval_kernel(a_ref, cls_ref, thr_ref, proc_ref, hops_ref, dc_ref,
                      consts_ref, obj_ref):
    a = a_ref[...]            # [TP, K, L]
    cls = cls_ref[...]        # [K, 3]
    thr = thr_ref[...]        # [K, L]
    proc = proc_ref[...]
    hops = hops_ref[...]
    dc = dc_ref[...]          # [8, L]
    consts = consts_ref[...]  # [12]

    n_req = cls[:, 0]
    tok = cls[:, 1]
    mem = cls[:, 2]

    nodes, tdp, cop, tou, ci, wi, bw, unused_pr = (dc[i] for i in range(8))
    (epoch_s, pr_on, h_water, d_ratio, ei_pot, ei_waste, k_media,
     q_coef, u_max, cold_frac) = (consts[i] for i in range(10))

    # demand contraction over classes: VPU multiply-reduce over K
    w = n_req * tok                                   # [K]
    node_s = jnp.sum(a * (w[:, None] / thr)[None], axis=1)    # [TP, L]
    reqs_l = jnp.sum(a * n_req[None, :, None], axis=1)        # [TP, L]

    # node states (Eq. 5-6)
    on = jnp.minimum(node_s / epoch_s, nodes[None])
    util = on / jnp.maximum(nodes, 1.0)[None]
    e_it = (on * pr_on + (nodes[None] - on) * unused_pr) * tdp[None] * epoch_s

    # cooling + support (Eq. 7-10), cost (Eq. 11)
    e_tot = e_it * (1.0 + 3.0 / cop + 0.13)[None]
    e_tot_kwh = e_tot / J_PER_KWH
    cost = jnp.sum(e_tot_kwh * tou[None], axis=-1)

    # water (Eq. 12-15)
    w_e = e_it / h_water
    w_b = w_e / (1.0 - d_ratio)
    w_grid = e_tot_kwh * wi[None]
    water = jnp.sum(w_e + w_b + w_grid, axis=-1)

    # carbon (Eq. 16-18)
    c_grid = ci[None] * e_tot_kwh
    c_w = ((w_e + w_b) * ei_pot + w_grid * ei_waste) * ci[None]
    carbon = jnp.sum(c_grid + c_w, axis=-1)

    # TTFT (Eq. 1-4)
    base = cold_frac * mem[:, None] / bw[None, :] + 2.0 * hops * k_media + proc
    t_base = jnp.sum(a * (n_req[:, None] * base)[None], axis=(1, 2))
    queue = q_coef * util / (1.0 - jnp.minimum(util, u_max))
    t_queue = jnp.sum(reqs_l * queue, axis=-1)
    total_req = jnp.maximum(jnp.sum(n_req), 1.0)
    ttft = (t_base + t_queue) / total_req

    obj_ref[...] = jnp.stack([ttft, carbon, water, cost], axis=-1)


@functools.partial(jax.jit, static_argnames=("tp",))
def plan_eval(a, cls, thr, proc, hops, dc, consts, *, tp=shapes.TP):
    """Evaluate a population of plans a[P, K, L] -> obj[P, 4] via Pallas."""
    p, k, l = a.shape
    assert p % tp == 0, f"population {p} not a multiple of tile {tp}"
    grid = (p // tp,)
    whole = lambda shape: pl.BlockSpec(shape, lambda i: tuple(0 for _ in shape))
    return pl.pallas_call(
        _plan_eval_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tp, k, l), lambda i: (i, 0, 0)),
            whole(cls.shape),
            whole(thr.shape),
            whole(proc.shape),
            whole(hops.shape),
            whole(dc.shape),
            whole(consts.shape),
        ],
        out_specs=pl.BlockSpec((tp, shapes.N_OBJ), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((p, shapes.N_OBJ), a.dtype),
        interpret=True,
    )(a, cls, thr, proc, hops, dc, consts)
