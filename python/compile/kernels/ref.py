"""Pure-jnp oracle for the batched plan evaluator (Eqs. 1-18 of the paper).

This is the correctness reference: the Pallas kernel in plan_eval.py and the
rust `eval/` module must both agree with this function.  Every physical input
is a runtime argument (nothing baked), so rust owns the constants.

Inputs
------
a       f32[P, K, L]   plan population: fraction of class k routed to DC l
cls     f32[K, 3]      per-class [n_req, tok_out, model_mem_gb]
thr     f32[K, L]      node throughput for class k at DC l, tokens/s
proc    f32[K, L]      time-to-first-token processing term, seconds (Eq. 4)
hops    f32[K, L]      router hops from class k's origin region to DC l
dc      f32[8, L]      rows: nodes, tdp_w, cop, tou, ci, wi, bw_gbs, unused_pr
consts  f32[12]        see shapes.CONSTS

Returns
-------
obj     f32[P, 4]      [ttft_s, carbon_kg, water_l, cost_usd]

Units
-----
energy J internally, kWh for grid-coupled terms; water liters; carbon kg
(ci is kg/kWh); cost currency units (TOU is per kWh).
"""

import jax.numpy as jnp

J_PER_KWH = 3.6e6


def plan_eval_ref(a, cls, thr, proc, hops, dc, consts):
    n_req = cls[:, 0]          # [K]
    tok = cls[:, 1]            # [K]
    mem = cls[:, 2]            # [K] GB

    nodes = dc[0]              # [L]
    tdp = dc[1]
    cop = dc[2]
    tou = dc[3]
    ci = dc[4]
    wi = dc[5]
    bw = dc[6]
    unused_pr = dc[7]

    epoch_s = consts[0]
    pr_on = consts[1]
    h_water = consts[2]
    d_ratio = consts[3]
    ei_pot = consts[4]
    ei_waste = consts[5]
    k_media = consts[6]
    q_coef = consts[7]
    u_max = consts[8]
    cold_frac = consts[9]

    # --- demand contraction over classes (Eq. 1 aggregate) ----------------
    w = n_req * tok                                          # tokens/class [K]
    node_s = jnp.einsum("pkl,kl->pl", a, w[:, None] / thr)   # node-seconds
    reqs_l = jnp.einsum("pkl,k->pl", a, n_req)               # requests per DC

    # --- node states (Eq. 5-6) ---------------------------------------------
    on = jnp.minimum(node_s / epoch_s, nodes)                # nodes ON [P, L]
    util = on / jnp.maximum(nodes, 1.0)
    e_it = (on * pr_on + (nodes - on) * unused_pr) * tdp * epoch_s  # J

    # --- cooling + support (Eq. 7-10) ---------------------------------------
    e_tot = e_it * (1.0 + 3.0 / cop + 0.13)                  # J
    e_tot_kwh = e_tot / J_PER_KWH

    # --- energy cost (Eq. 11) ------------------------------------------------
    cost = jnp.sum(e_tot_kwh * tou, axis=-1)                 # [P]

    # --- water (Eq. 12-15) ----------------------------------------------------
    w_e = e_it / h_water                                     # liters evaporated
    w_b = w_e / (1.0 - d_ratio)
    w_grid = e_tot_kwh * wi
    water = jnp.sum(w_e + w_b + w_grid, axis=-1)             # [P] liters

    # --- carbon (Eq. 16-18) ----------------------------------------------------
    c_grid = ci * e_tot_kwh
    c_w = ((w_e + w_b) * ei_pot + w_grid * ei_waste) * ci
    carbon = jnp.sum(c_grid + c_w, axis=-1)                  # [P] kg

    # --- TTFT (Eq. 1-4) ---------------------------------------------------------
    base = cold_frac * mem[:, None] / bw[None, :] \
        + 2.0 * hops * k_media + proc                        # [K, L]
    t_base = jnp.einsum("pkl,kl->p", a, n_req[:, None] * base)
    queue = q_coef * util / (1.0 - jnp.minimum(util, u_max))
    t_queue = jnp.sum(reqs_l * queue, axis=-1)
    total_req = jnp.maximum(jnp.sum(n_req), 1.0)
    ttft = (t_base + t_queue) / total_req                    # [P] seconds

    return jnp.stack([ttft, carbon, water, cost], axis=-1)


def predictor_ref(x, y, xq, lambdas):
    """Ridge-regression oracle for the workload predictor.

    x f32[H, F], y f32[H], xq f32[F], lambdas f32[D]
    returns (preds f32[D], rmse f32[D]) — one ridge fit per lambda,
    solved exactly (the HLO version uses conjugate gradients).
    """
    h = x.shape[0]
    xtx = x.T @ x
    xty = x.T @ y
    eye = jnp.eye(x.shape[1], dtype=x.dtype)

    def fit(lam):
        beta = jnp.linalg.solve(xtx + lam * eye, xty)
        resid = x @ beta - y
        rmse = jnp.sqrt(jnp.sum(resid * resid) / h)
        return xq @ beta, rmse

    preds, rmses = [], []
    for i in range(lambdas.shape[0]):
        p, r = fit(lambdas[i])
        preds.append(p)
        rmses.append(r)
    return jnp.stack(preds), jnp.stack(rmses)
