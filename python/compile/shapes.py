"""Canonical AOT shapes shared by the L1 kernel, L2 graph, AOT lowering and
the rust runtime (via artifacts/manifest.json).

The rust coordinator pads its population / datacenter arrays to these shapes
before dispatching to the PJRT executable, and the manifest check in
`rust/src/runtime/` refuses to run against artifacts with different shapes.
"""

# --- plan evaluator -------------------------------------------------------
P = 128   # population tile: plans evaluated per dispatch
K = 8     # request classes (= origin regions x models = 4 x 2)
L = 16    # datacenter slots (12 real + 4 padding, lane-friendly)
TP = 8    # pallas grid tile over P

# dc parameter matrix rows (dc[8, L])
DC_ROWS = ("nodes", "tdp_w", "cop", "tou", "ci", "wi", "bw_gbs", "unused_pr")

# consts vector layout (consts[12])
CONSTS = (
    "epoch_s",      # epoch length, seconds
    "pr_on",        # power ratio of an ON node (x TDP)
    "h_water",      # heat absorbed per liter evaporated, J/L
    "d_ratio",      # blowdown solids ratio D in Eq. 13
    "ei_pot",       # potable-water treatment energy intensity, kWh/L
    "ei_waste",     # wastewater treatment energy intensity, kWh/L
    "k_media",      # per-hop inter-router latency, seconds
    "q_coef",       # queueing delay coefficient, seconds
    "u_max",        # utilisation clip for the queueing term
    "cold_frac",    # fraction of requests paying the model-load latency
    "pad0",
    "pad1",
)

N_OBJ = 4  # [ttft_s, carbon_kg, water_l, cost_usd]

# --- workload predictor ----------------------------------------------------
H = 192   # history window, epochs
F = 8     # features: [1, lag1, lag2, lag3, lag4, sin, cos, lag96]
D = 4     # ridge lambdas tried per fit
CG_ITERS = 12  # conjugate-gradient iterations (F=8 SPD system: 12 = 1.5x margin)
