"""AOT lowering: jax (L2 + L1) -> HLO *text* -> artifacts/ for the rust runtime.

HLO text (NOT ``lowered.compile()`` / serialized HloModuleProto) is the
interchange format: jax >= 0.5 emits protos with 64-bit instruction ids that
xla_extension 0.5.1 (what the published ``xla`` 0.1.6 crate binds) rejects;
the text parser reassigns ids and round-trips cleanly.  See
/opt/xla-example/README.md and gen_hlo.py there.

Outputs (under --out-dir, default ../artifacts):

    plan_eval.hlo.txt   obj[P,4] = f(a[P,K,L], cls[K,3], thr[K,L], proc[K,L],
                                     hops[K,L], dc[8,L], consts[12])
    predictor.hlo.txt   (preds[D], rmse[D]) = f(x[H,F], y[H], xq[F], lam[D])
    manifest.json       shapes + argument layouts; the rust runtime refuses
                        to run against a manifest it does not recognise

Run via ``make artifacts`` (no-op when inputs are unchanged).
"""

import argparse
import hashlib
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from compile import shapes
from compile.model import plan_eval_model, predictor_model

F32 = jnp.float32


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_plan_eval() -> str:
    s = jax.ShapeDtypeStruct
    args = (
        s((shapes.P, shapes.K, shapes.L), F32),   # a
        s((shapes.K, 3), F32),                    # cls
        s((shapes.K, shapes.L), F32),             # thr
        s((shapes.K, shapes.L), F32),             # proc
        s((shapes.K, shapes.L), F32),             # hops
        s((8, shapes.L), F32),                    # dc
        s((12,), F32),                            # consts
    )
    return to_hlo_text(jax.jit(plan_eval_model).lower(*args))


def lower_predictor() -> str:
    s = jax.ShapeDtypeStruct
    args = (
        s((shapes.H, shapes.F), F32),             # x
        s((shapes.H,), F32),                      # y
        s((shapes.F,), F32),                      # xq
        s((shapes.D,), F32),                      # lambdas
    )
    return to_hlo_text(jax.jit(predictor_model).lower(*args))


def manifest() -> dict:
    return {
        "version": 1,
        "plan_eval": {
            "file": "plan_eval.hlo.txt",
            "population": shapes.P,
            "classes": shapes.K,
            "dc_slots": shapes.L,
            "tile": shapes.TP,
            "n_obj": shapes.N_OBJ,
            "dc_rows": list(shapes.DC_ROWS),
            "consts": list(shapes.CONSTS),
            "objectives": ["ttft_s", "carbon_kg", "water_l", "cost_usd"],
        },
        "predictor": {
            "file": "predictor.hlo.txt",
            "window": shapes.H,
            "features": shapes.F,
            "lambdas": shapes.D,
            "cg_iters": shapes.CG_ITERS,
        },
    }


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default=os.path.join("..", "artifacts"))
    ap.add_argument("--out", default=None,
                    help="legacy single-file target (ignored; kept for Make)")
    args = ap.parse_args()
    out_dir = args.out_dir
    if args.out is not None:
        out_dir = os.path.dirname(args.out) or out_dir
    os.makedirs(out_dir, exist_ok=True)

    man = manifest()
    for name, lower in (("plan_eval", lower_plan_eval),
                        ("predictor", lower_predictor)):
        text = lower()
        path = os.path.join(out_dir, man[name]["file"])
        with open(path, "w") as f:
            f.write(text)
        man[name]["sha256"] = hashlib.sha256(text.encode()).hexdigest()
        print(f"wrote {path} ({len(text)} chars)")

    man_path = os.path.join(out_dir, "manifest.json")
    with open(man_path, "w") as f:
        json.dump(man, f, indent=2, sort_keys=True)
    print(f"wrote {man_path}")


if __name__ == "__main__":
    main()
