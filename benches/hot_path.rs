//! Hot-path microbenchmarks: the plan evaluator (native scalar, native
//! batch-parallel, AOT/PJRT), the GBDT surrogate, the MCMF solver, the
//! predictor fit, a full optimizer generation, the global-vs-region
//! decomposed search at L=48/256/512, the temporal-shift planner's
//! per-epoch overhead, and the optimality-gap oracle's per-epoch solve.
//! These are the numbers the §Perf iteration log in EXPERIMENTS.md
//! tracks.

use slit::cluster::build_panels;
use slit::config::{SystemConfig, EVAL_POPULATION};
use slit::eval::{AnalyticEvaluator, BatchEvaluator, EvalConsts, MemoizedEvaluator};
use slit::opt::{Gbdt, GbdtConfig, SlitOptimizer};
use slit::plan::Plan;
use slit::power::GridSignals;
use slit::predictor::{fit_window, features};
use slit::runtime::{artifacts_dir, artifacts_present, Engine, HloPlanEvaluator};
use slit::trace::Trace;
use slit::util::benchkit::Bench;
use slit::util::rng::Rng;
use slit::util::threadpool;

fn main() {
    let quick = std::env::var("BENCH_QUICK").is_ok();
    let mut bench = Bench::new("hot_path");
    let cfg = SystemConfig::paper_default();
    let signals = GridSignals::generate(&cfg, 8, 3);
    let trace = Trace::generate(&cfg, 8, 3);
    let (cp, dp) = build_panels(&cfg, &signals, 4, &trace.epochs[4], 0.0);
    let ev =
        AnalyticEvaluator::new(cp, dp, EvalConsts::from_physics(&cfg.physics));

    let mut rng = Rng::new(1);
    let plans: Vec<Plan> = (0..EVAL_POPULATION)
        .map(|_| Plan::random(cfg.num_classes(), ev.dcs(), 0.5, &mut rng))
        .collect();

    // --- L3 native evaluator ------------------------------------------------
    bench.bench_throughput("eval: native single plan", 1.0, "plan", || {
        core::hint::black_box(ev.evaluate(&plans[0]));
    });
    threadpool::set_thread_override(1);
    bench.bench_throughput(
        "eval: native batch 128 (serial)",
        EVAL_POPULATION as f64,
        "plan",
        || {
            core::hint::black_box(ev.evaluate_batch(&plans));
        },
    );
    threadpool::set_thread_override(0);
    bench.bench_throughput(
        "eval: native batch 128 (parallel)",
        EVAL_POPULATION as f64,
        "plan",
        || {
            core::hint::black_box(ev.evaluate_batch(&plans));
        },
    );
    {
        // optimizer-shaped stream: each step re-evaluates the surviving
        // neighbours of the previous one, so half of every batch repeats —
        // the memo answers repeats from the fingerprint cache
        let memo = MemoizedEvaluator::new(&ev);
        let warm = memo.eval_batch(&plans); // cache warmed once
        core::hint::black_box(warm);
        bench.bench_throughput(
            "eval: batch 128 (parallel+memo, warm)",
            EVAL_POPULATION as f64,
            "plan",
            || {
                core::hint::black_box(memo.eval_batch(&plans));
            },
        );
    }

    // headline number for the PR: the optimizer's two-pass eval stream
    // (cold batch + full revisit) — serial/no-memo vs parallel+memo
    {
        let reps = 40;
        let stream = |evaluator: &dyn BatchEvaluator| {
            // cold pass + revisit pass, as the local search produces when
            // a step's best candidates survive into the next step
            core::hint::black_box(evaluator.eval_batch(&plans));
            core::hint::black_box(evaluator.eval_batch(&plans));
        };
        threadpool::set_thread_override(1);
        let t = std::time::Instant::now();
        for _ in 0..reps {
            stream(&ev);
        }
        let serial_s = t.elapsed().as_secs_f64() / reps as f64;
        threadpool::set_thread_override(0);
        let t = std::time::Instant::now();
        for _ in 0..reps {
            let memo = MemoizedEvaluator::new(&ev);
            stream(&memo);
        }
        let par_memo_s = t.elapsed().as_secs_f64() / reps as f64;
        bench.record_value(
            "eval stream 2x128: serial/no-memo",
            serial_s * 1e6,
            "us",
        );
        bench.record_value(
            "eval stream 2x128: parallel+memo",
            par_memo_s * 1e6,
            "us",
        );
        bench.record_value(
            "eval stream 2x128: speedup (target >= 2x)",
            serial_s / par_memo_s.max(1e-12),
            "x",
        );
    }

    // headline number for the delta-evaluation PR: scoring one-row
    // neighbours against cached epoch aggregates (O(L)) vs the full O(K*L)
    // contraction — this is what the SLIT local search now does for every
    // surviving candidate
    {
        let base = &plans[0];
        let agg = ev.aggregate(base.as_slice());
        let mut r = Rng::new(11);
        let cands: Vec<(usize, Plan)> = (0..256)
            .map(|_| {
                let k = r.below(cfg.num_classes());
                let to = r.below(ev.dcs());
                (k, base.shifted_toward(k, to, r.range(0.2, 0.8)))
            })
            .collect();
        let reps = if quick { 20 } else { 200 };
        let t = std::time::Instant::now();
        for _ in 0..reps {
            for (_, c) in &cands {
                core::hint::black_box(ev.evaluate(c));
            }
        }
        let full_s = t.elapsed().as_secs_f64() / reps as f64;
        let t = std::time::Instant::now();
        for _ in 0..reps {
            for (k, c) in &cands {
                core::hint::black_box(ev.evaluate_delta(
                    &agg,
                    *k,
                    base.row(*k),
                    c.row(*k),
                ));
            }
        }
        let delta_s = t.elapsed().as_secs_f64() / reps as f64;
        bench.record_value(
            "neighbor scoring 256: full contraction",
            full_s * 1e6,
            "us",
        );
        bench.record_value(
            "neighbor scoring 256: delta (O(L))",
            delta_s * 1e6,
            "us",
        );
        bench.record_value(
            "neighbor scoring: delta speedup (target >= 4x)",
            full_s / delta_s.max(1e-12),
            "x",
        );
    }

    // tiled-DC scaling: the same delta rescore (scratch copy_from +
    // apply_row_delta + finish, exactly the SLIT search loop) on an
    // inline-tile fleet (L=16) vs a spilled planet-scale fleet (L=48).
    // The claim the DcVec refactor makes: per-DC cost scales <= linearly
    // in L — the spill adds no super-linear overhead.
    {
        use slit::eval::PlanAgg;
        use slit::scenario::global_fleet_datacenters;

        let fleet48 = global_fleet_datacenters(6);
        let time_at = |dcs: usize, reps: usize| -> f64 {
            let mut c = SystemConfig::paper_default();
            c.datacenters = fleet48[..dcs].to_vec();
            let signals = GridSignals::generate(&c, 8, 3);
            let trace = Trace::generate(&c, 8, 3);
            let (cp, dp) = build_panels(&c, &signals, 4, &trace.epochs[4], 0.0);
            let e =
                AnalyticEvaluator::new(cp, dp, EvalConsts::from_physics(&c.physics));
            let mut r = Rng::new(17);
            let base = Plan::random(c.num_classes(), dcs, 0.5, &mut r);
            let agg = e.aggregate(base.as_slice());
            let cands: Vec<(usize, Plan)> = (0..256)
                .map(|_| {
                    let k = r.below(c.num_classes());
                    let to = r.below(dcs);
                    (k, base.shifted_toward(k, to, r.range(0.2, 0.8)))
                })
                .collect();
            let mut scratch = PlanAgg::zeros(dcs);
            let t = std::time::Instant::now();
            for _ in 0..reps {
                for (k, cand) in &cands {
                    scratch.copy_from(&agg);
                    e.apply_row_delta(
                        &mut scratch,
                        *k,
                        base.row(*k),
                        cand.row(*k),
                    );
                    core::hint::black_box(e.finish(&scratch));
                }
            }
            t.elapsed().as_secs_f64() / (reps * cands.len()) as f64
        };
        let reps = if quick { 20 } else { 200 };
        let t16 = time_at(16, reps);
        let t48 = time_at(48, reps);
        bench.record_value("delta rescore: L=16 (inline tile)", t16 * 1e9, "ns");
        bench.record_value("delta rescore: L=48 (spilled tile)", t48 * 1e9, "ns");
        bench.record_value(
            "delta rescore: per-DC cost L=48/L=16 (target <= ~1x, linear)",
            (t48 / 48.0) / (t16 / 16.0).max(1e-12),
            "x",
        );
    }

    // candidate batch build: SoA arena generation vs per-candidate Plan
    // clones (the pre-arena code path)
    {
        let currents: Vec<&Plan> = plans.iter().take(24).collect();
        let neighbors = 8;
        let step = 0.25;
        let reps = if quick { 40 } else { 400 };
        let mut arena =
            slit::plan::PlanBatch::new(cfg.num_classes(), ev.dcs());
        arena.reserve(currents.len() * neighbors);
        let t = std::time::Instant::now();
        for rep in 0..reps {
            let mut r = Rng::new(5000 + rep as u64);
            arena.clear();
            for cur in &currents {
                arena.push_neighbors_of(
                    cur.as_slice(),
                    neighbors,
                    step,
                    &mut r,
                );
            }
            core::hint::black_box(arena.len());
        }
        let arena_s = t.elapsed().as_secs_f64() / reps as f64;
        let t = std::time::Instant::now();
        for rep in 0..reps {
            let mut r = Rng::new(5000 + rep as u64);
            let mut cands: Vec<Plan> = Vec::new();
            for cur in &currents {
                cands.extend(slit::util::benchkit::clone_path_neighbors(
                    cur, neighbors, step, &mut r,
                ));
            }
            core::hint::black_box(&cands);
        }
        let clone_s = t.elapsed().as_secs_f64() / reps as f64;
        bench.record_value(
            "candidate build 24x8: plan clones",
            clone_s * 1e6,
            "us",
        );
        bench.record_value(
            "candidate build 24x8: SoA arena",
            arena_s * 1e6,
            "us",
        );
        bench.record_value(
            "candidate build: arena speedup",
            clone_s / arena_s.max(1e-12),
            "x",
        );
    }

    // memo cache under contention: concurrent warm-hit sweeps against one
    // global lock vs 16 fingerprint shards
    {
        let mut r = Rng::new(13);
        let streams: Vec<Vec<Plan>> = (0..64)
            .map(|_| {
                (0..16)
                    .map(|_| {
                        Plan::random(cfg.num_classes(), ev.dcs(), 0.5, &mut r)
                    })
                    .collect()
            })
            .collect();
        let run = |shards: usize| -> f64 {
            let memo = MemoizedEvaluator::with_shards(&ev, shards);
            for s in &streams {
                memo.eval_batch(s);
            }
            let reps = if quick { 5 } else { 50 };
            let t = std::time::Instant::now();
            for _ in 0..reps {
                core::hint::black_box(threadpool::par_map(&streams, |s| {
                    memo.eval_batch(s)
                }));
            }
            t.elapsed().as_secs_f64() / reps as f64
        };
        let global_s = run(1);
        let sharded_s = run(16);
        bench.record_value(
            "memo warm sweep 64x16: global lock",
            global_s * 1e6,
            "us",
        );
        bench.record_value(
            "memo warm sweep 64x16: 16 shards",
            sharded_s * 1e6,
            "us",
        );
        bench.record_value(
            "memo contention: shard speedup",
            global_s / sharded_s.max(1e-12),
            "x",
        );
    }

    // --- serve loop ----------------------------------------------------------
    // the sharded-worker TCP front under open-loop (Poisson) load: achieved
    // req/s and TTFT/RTT p99 at a fixed transport-error budget. "saturated"
    // is a correct structured reply (the fleet is finite), so the error
    // budget covers transport/validation failures and dropped replies only.
    {
        use slit::coordinator::{
            run_loadgen, serve_forever, ArrivalMode, Coordinator,
            CoordinatorConfig, DispatchPolicy, LoadgenConfig,
        };

        let boot = |policy: DispatchPolicy| {
            let mut c = SystemConfig::small_test();
            c.opt.generations = 2;
            c.opt.population = 8;
            let mut ccfg = CoordinatorConfig {
                plan_budget_s: 0.2,
                ..Default::default()
            };
            ccfg.batcher.policy = policy;
            Coordinator::new(c, ccfg, None)
        };

        let c = boot(DispatchPolicy::Llf);
        let handle = serve_forever(std::sync::Arc::clone(&c), 0)
            .expect("bind ephemeral");
        let lcfg = LoadgenConfig {
            port: handle.port,
            mode: ArrivalMode::Open,
            conns: if quick { 4 } else { 8 },
            rate_rps: if quick { 4_000.0 } else { 24_000.0 },
            duration_s: if quick { 0.5 } else { 3.0 },
            batch: 8,
            ..Default::default()
        };
        let r = run_loadgen(&lcfg).expect("loadgen");
        let transport_err_rate = (r.errors + r.dropped_replies) as f64
            / (r.sent as f64).max(1.0);
        bench.record_value(
            "serve: open-loop achieved (target >= 10k)",
            r.achieved_rps(),
            "req/s",
        );
        bench.record_value("serve: rtt p99", r.rtt.p99() * 1e3, "ms");
        bench.record_value("serve: ttft p99", r.ttft.p99() * 1e3, "ms");
        bench.record_value(
            "serve: transport error rate (budget 0.01)",
            transport_err_rate,
            "frac",
        );
        bench.record_value(
            "serve: sender behind-schedule events",
            r.behind as f64,
            "count",
        );
        c.stop();
        handle.thread.join().expect("server thread");

        // LLF-vs-FCFS dispatch under a saturating batch stream (in-process,
        // deterministic — no socket noise): the worst class's p99 TTFT
        // divided by its model's TTFT SLO, per policy
        let waves = if quick { 16 } else { 64 };
        let slack = |policy: DispatchPolicy| -> f64 {
            use slit::config::{MODELS, REGIONS};
            let c = boot(policy);
            for wave in 0..waves {
                let reqs: Vec<(usize, usize, u32, u32)> = (0..64)
                    .map(|i| ((i + wave) % REGIONS, i % MODELS, 128, 256))
                    .collect();
                core::hint::black_box(c.handle_batch(&reqs));
            }
            let m = c.metrics_snapshot();
            m.class_ttft
                .iter()
                .enumerate()
                .filter(|(_, h)| h.count() > 0)
                .map(|(k, h)| {
                    h.p99() / c.cfg.models[k % MODELS].ttft_slo_s
                })
                .fold(0.0f64, f64::max)
        };
        let llf = slack(DispatchPolicy::Llf);
        let fcfs = slack(DispatchPolicy::Fcfs);
        bench.record_value("dispatch: LLF worst p99/SLO", llf, "frac");
        bench.record_value("dispatch: FCFS worst p99/SLO", fcfs, "frac");
        bench.record_value(
            "dispatch: FCFS/LLF worst-slack ratio (>= 1 means LLF wins)",
            fcfs / llf.max(1e-12),
            "x",
        );
    }

    // --- AOT / PJRT ----------------------------------------------------------
    if slit::runtime::pjrt_enabled() && artifacts_present() {
        let engine = Engine::load(&artifacts_dir()).expect("engine");
        let hlo = HloPlanEvaluator::from_analytic(engine, &ev);
        bench.bench_throughput(
            "eval: pjrt-hlo batch 128",
            EVAL_POPULATION as f64,
            "plan",
            || {
                core::hint::black_box(hlo.eval_batch(&plans));
            },
        );
    } else {
        eprintln!("  (skipping pjrt-hlo benches: artifacts not built)");
    }

    // --- GBDT surrogate ------------------------------------------------------
    let xs: Vec<Vec<f64>> = plans
        .iter()
        .map(|p| p.as_slice().to_vec())
        .collect();
    let ys: Vec<f64> = plans.iter().map(|p| ev.evaluate(p)[1]).collect();
    let gcfg = GbdtConfig {
        trees: cfg.opt.gbdt_trees,
        depth: cfg.opt.gbdt_depth,
        learning_rate: cfg.opt.gbdt_lr,
        min_leaf: cfg.opt.gbdt_min_leaf,
        feature_sample: 16,
    };
    bench.bench("gbdt: fit 128x96", || {
        let mut r = Rng::new(2);
        core::hint::black_box(Gbdt::fit(&xs, &ys, &gcfg, &mut r));
    });
    let mut r2 = Rng::new(3);
    let model = Gbdt::fit(&xs, &ys, &gcfg, &mut r2);
    bench.bench_throughput("gbdt: predict", 1.0, "plan", || {
        core::hint::black_box(model.predict(plans[0].as_slice()));
    });
    {
        // flat-tree batch ranking over one arena-shaped matrix (how the
        // surrogate scores a step's merged candidate batch)
        let stride = cfg.num_classes() * ev.dcs();
        let flat: Vec<f64> = plans
            .iter()
            .flat_map(|p| p.as_slice().iter().copied())
            .collect();
        let mut preds: Vec<f64> = Vec::new();
        bench.bench_throughput(
            "gbdt: predict_batch 128 (flat trees)",
            EVAL_POPULATION as f64,
            "plan",
            || {
                model.predict_batch_into(&flat, stride, &mut preds);
                core::hint::black_box(preds.len());
            },
        );
    }

    // --- optimizer -----------------------------------------------------------
    let mut opt_cfg = cfg.opt.clone();
    opt_cfg.generations = 1;
    bench.bench("slit: one generation (analytic)", || {
        let mut o = SlitOptimizer::new(
            opt_cfg.clone(),
            cfg.num_classes(),
            ev.dcs(),
            7,
        );
        core::hint::black_box(o.optimize(&ev).evaluations);
    });

    // --- region-decomposed search --------------------------------------------
    // the PR 10 tentpole: per-epoch SLIT search wall-clock, forced global
    // walk vs the price-coordinated region decomposition on identical
    // panels — at the planet-scale fleet (L=48, below the auto threshold)
    // and the edge-fleet scales the decomposition exists for (L=256 and
    // L=512, where the speedup target is >= 3x: the delta core shrinks to
    // O(L/4) per move and the four subsearches run concurrently)
    {
        use slit::opt::{SearchMode, SlitOptions};
        use slit::scenario::global_fleet_datacenters;

        for (per_zone, l) in [(6usize, 48usize), (32, 256), (64, 512)] {
            let mut c = SystemConfig::paper_default();
            c.datacenters = global_fleet_datacenters(per_zone);
            c.opt.generations = if quick { 1 } else { 2 };
            c.opt.search_steps = if quick { 3 } else { 6 };
            c.opt.budget_s = 600.0;
            let signals = GridSignals::generate(&c, 8, 3);
            let trace = Trace::generate(&c, 8, 3);
            let (cp, dp) =
                build_panels(&c, &signals, 4, &trace.epochs[4], 0.0);
            let e = AnalyticEvaluator::new(
                cp,
                dp,
                EvalConsts::from_physics(&c.physics),
            );
            let regions: Vec<usize> =
                c.datacenters.iter().map(|d| d.region).collect();
            let run = |mode: SearchMode| -> f64 {
                let t = std::time::Instant::now();
                let mut o = SlitOptimizer::new(
                    c.opt.clone(),
                    c.num_classes(),
                    l,
                    7,
                )
                .with_options(SlitOptions {
                    search_mode: Some(mode),
                    ..SlitOptions::default()
                })
                .with_regions(regions.clone());
                core::hint::black_box(o.optimize(&e).evaluations);
                t.elapsed().as_secs_f64()
            };
            let global_s = run(SearchMode::Global);
            let region_s = run(SearchMode::RegionDecomposed);
            bench.record_value(
                &format!("search: global walk (L={l})"),
                global_s * 1e3,
                "ms",
            );
            bench.record_value(
                &format!("search: region-decomposed (L={l})"),
                region_s * 1e3,
                "ms",
            );
            let name = if l >= 256 {
                format!("search: region speedup L={l} (target >= 3x)")
            } else {
                format!("search: region speedup L={l}")
            };
            bench.record_value(
                &name,
                global_s / region_s.max(1e-12),
                "x",
            );
        }
    }

    // --- Helix MCMF ----------------------------------------------------------
    bench.bench("helix: mcmf plan for one epoch", || {
        use slit::cluster::ClusterState;
        use slit::sim::{EpochContext, Scheduler};
        let predicted = trace.epochs[4].clone();
        let cluster = ClusterState::from_config(&cfg);
        let ctx = EpochContext {
            cfg: &cfg,
            epoch: 4,
            predicted: &predicted,
            evaluator: &ev,
            cluster: &cluster,
            prev: None,
        };
        let mut h = slit::baselines::HelixScheduler;
        core::hint::black_box(h.plan(&ctx));
    });

    // --- optimality-gap oracle -----------------------------------------------
    // the certified lower-bound solve (four scalarizations, each one MCMF
    // run plus the TTFT queue-hull expansion) that SimSession::step now
    // pays every epoch — tracked at both fleet scales so the per-epoch
    // tax stays visibly small next to the plan search above
    {
        use slit::config::N_OBJ;
        use slit::opt::epoch_lower_bound;
        use slit::scenario::global_fleet_datacenters;

        let fleet48 = global_fleet_datacenters(6);
        let eval_at = |dcs: usize| -> AnalyticEvaluator {
            let mut c = SystemConfig::paper_default();
            c.datacenters = fleet48[..dcs].to_vec();
            let signals = GridSignals::generate(&c, 8, 3);
            let trace = Trace::generate(&c, 8, 3);
            let (cp, dp) = build_panels(&c, &signals, 4, &trace.epochs[4], 0.0);
            AnalyticEvaluator::new(cp, dp, EvalConsts::from_physics(&c.physics))
        };
        let ev16 = eval_at(16);
        bench.bench("oracle: per-epoch solve (L=16)", || {
            for obj in 0..N_OBJ {
                core::hint::black_box(epoch_lower_bound(&ev16, obj));
            }
        });
        let ev48 = eval_at(48);
        bench.bench("oracle: per-epoch solve (L=48)", || {
            for obj in 0..N_OBJ {
                core::hint::black_box(epoch_lower_bound(&ev48, obj));
            }
        });
    }

    // --- predictor ------------------------------------------------------------
    let series: Vec<f64> = (0..192)
        .map(|t| 1000.0 + 300.0 * (t as f64 * 0.065).sin())
        .collect();
    bench.bench("predictor: ridge fit (window 192)", || {
        let scale = 1000.0;
        let mut xs = Vec::new();
        let mut ys = Vec::new();
        for t in 96..series.len() {
            xs.push(features(&series, t, scale, 96));
            ys.push(series[t] / scale);
        }
        core::hint::black_box(fit_window(&xs, &ys, 0.1));
    });

    // --- temporal shifting ---------------------------------------------------
    // the deferral layer's per-epoch overhead inside SimSession::step: one
    // forecaster observe + refit across all site series, a horizon
    // forecast, and the queue drain — this must stay negligible next to
    // the SLIT plan search it precedes
    {
        use slit::opt::{ShiftPolicy, TemporalShifter};
        use slit::scenario::Scenario;

        let mut base = SystemConfig::small_test();
        base.epochs = 48;
        let world = Scenario::BatchOvernight.build(&base, base.epochs, 9);
        let t = std::time::Instant::now();
        let mut sh = TemporalShifter::new(
            &world.cfg,
            &world.trace,
            ShiftPolicy::Forecast,
        );
        bench.record_value(
            "shift: forecaster warm-start (one-time)",
            t.elapsed().as_secs_f64() * 1e3,
            "ms",
        );
        let epochs = world.cfg.epochs;
        let t = std::time::Instant::now();
        for e in 0..epochs {
            let (ci, wi, tou) = world.signals.at(e);
            core::hint::black_box(sh.step(
                e,
                epochs - 1,
                &world.trace.epochs[e],
                &ci,
                &wi,
                &tou,
            ));
        }
        let step_s = t.elapsed().as_secs_f64() / epochs as f64;
        bench.record_value(
            "shift: planner step per epoch (forecast policy)",
            step_s * 1e6,
            "us",
        );
        let (offered, released, expired) = sh.totals();
        assert_eq!(offered, released + expired + sh.queue_mass());
    }

    // --- degraded-signal feed ------------------------------------------------
    // the believed-panel resolve SimSession::step pays every epoch: one
    // feed observe (delivery + plausibility gates + fleet median) plus the
    // robust-view read — must stay invisible next to the plan search it
    // feeds (the zero-heap pin for this loop lives in alloc_hotpath.rs)
    {
        use slit::signals::{SignalFeed, SignalPolicy};

        let epochs = 64;
        let sig = GridSignals::generate(&cfg, epochs, 3);
        let truth: Vec<_> = (0..epochs).map(|t| sig.at(t)).collect();
        let mut feed = SignalFeed::new(&cfg);
        // warm: median scratch + diurnal rings settle their capacities
        for (e, (ci, wi, tou)) in truth.iter().enumerate() {
            feed.observe(e, ci, wi, tou);
        }
        let reps = if quick { 20 } else { 200 };
        let t = std::time::Instant::now();
        for _ in 0..reps {
            for (e, (ci, wi, tou)) in truth.iter().enumerate() {
                feed.observe(e, ci, wi, tou);
                core::hint::black_box(feed.view(SignalPolicy::Robust));
            }
        }
        let resolve_s = t.elapsed().as_secs_f64() / (reps * epochs) as f64;
        bench.record_value(
            "signals: believed-panel resolve per epoch",
            resolve_s * 1e6,
            "us",
        );
    }

    bench.finish();
}
