//! Ablation benches for the design choices DESIGN.md calls out:
//!   * ML-guided vs unguided local search (the GBDT surrogate's value)
//!   * EA on vs off (escape from local optima)
//!   * population size scaling
//!   * workload predictor on vs off (plan vs stale-plan quality)
//! Reported as hypervolume / evaluation-efficiency values plus wall time.

use slit::cluster::build_panels;
use slit::config::{SystemConfig, N_OBJ, OBJ_NAMES};
use slit::eval::{AnalyticEvaluator, EvalConsts};
use slit::opt::{SlitOptimizer, SlitOptions};
use slit::pareto::hypervolume;
use slit::power::GridSignals;
use slit::scenario::Scenario;
use slit::trace::Trace;
use slit::util::benchkit::Bench;

fn make_eval(cfg: &SystemConfig) -> AnalyticEvaluator {
    let signals = GridSignals::generate(cfg, 8, 3);
    let trace = Trace::generate(cfg, 8, 3);
    let (cp, dp) =
        build_panels(cfg, &signals, 4, &trace.epochs[4], cfg.physics.pr_off);
    AnalyticEvaluator::new(cp, dp, EvalConsts::from_physics(&cfg.physics))
}

fn run(
    cfg: &SystemConfig,
    ev: &AnalyticEvaluator,
    options: SlitOptions,
    population: usize,
    seed: u64,
) -> (f64, usize, f64) {
    let mut opt_cfg = cfg.opt.clone();
    opt_cfg.population = population;
    opt_cfg.generations = 8;
    opt_cfg.budget_s = 30.0;
    let mut o = SlitOptimizer::new(
        opt_cfg,
        cfg.num_classes(),
        ev.dcs(),
        seed,
    )
    .with_options(options);
    let t = std::time::Instant::now();
    let out = o.optimize(ev);
    let (_, hi) = out.archive.bounds();
    let mut reference = [0.0; N_OBJ];
    for i in 0..N_OBJ {
        reference[i] = hi[i] * 1.1 + 1e-9;
    }
    (
        hypervolume(&out.archive.solutions, &reference, 20_000, 1),
        out.evaluations,
        t.elapsed().as_secs_f64(),
    )
}

fn main() {
    let mut bench = Bench::new("ablations");
    let cfg = SystemConfig::paper_default();
    let ev = make_eval(&cfg);

    let cases = [
        (
            "full (surrogate+ea)",
            SlitOptions {
                use_surrogate: true,
                use_ea: true,
                search_mode: None,
            },
        ),
        (
            "no surrogate",
            SlitOptions {
                use_surrogate: false,
                use_ea: true,
                search_mode: None,
            },
        ),
        (
            "no ea",
            SlitOptions {
                use_surrogate: true,
                use_ea: false,
                search_mode: None,
            },
        ),
        (
            "neither (random local search)",
            SlitOptions {
                use_surrogate: false,
                use_ea: false,
                search_mode: None,
            },
        ),
    ];
    // average over a few seeds to stabilise hypervolume
    for (name, options) in cases {
        let mut hv = 0.0;
        let mut evals = 0usize;
        let mut wall = 0.0;
        const SEEDS: u64 = 3;
        for seed in 0..SEEDS {
            let (h, e, w) = run(&cfg, &ev, options, cfg.opt.population, seed);
            hv += h;
            evals += e;
            wall += w;
        }
        bench.record_value(
            &format!("ablation: {name} hypervolume"),
            hv / SEEDS as f64,
            "hv",
        );
        bench.record_value(
            &format!("ablation: {name} evaluations"),
            evals as f64 / SEEDS as f64,
            "evals",
        );
        bench.record_value(
            &format!("ablation: {name} wall"),
            wall / SEEDS as f64,
            "s",
        );
    }

    for population in [8usize, 16, 24, 48] {
        let (h, e, _) =
            run(&cfg, &ev, SlitOptions::default(), population, 11);
        bench.record_value(
            &format!("ablation: population {population} hypervolume"),
            h,
            "hv",
        );
        bench.record_value(
            &format!("ablation: population {population} evaluations"),
            e as f64,
            "evals",
        );
    }

    // predictor ablation: simulate slit-balance with live prediction vs a
    // deliberately stale (previous-epoch) forecast by zeroing the predictor
    // via a one-epoch-shifted trace comparison
    {
        use slit::registry;
        use slit::sim::simulate;
        let mut small = SystemConfig::paper_default();
        small.epochs = 8;
        small.opt.budget_s = 0.4;
        for d in &mut small.datacenters {
            d.nodes_per_type =
                d.nodes_per_type.iter().map(|&n| n / 10).collect();
        }
        small.workload.base_requests_per_epoch /= 10.0;
        let trace = Trace::generate(&small, small.epochs, small.seed);
        let signals = GridSignals::generate(&small, small.epochs, small.seed);
        let mut sched =
            registry::build("slit-balance", &small, None).expect("scheduler");
        let live = simulate(&small, &trace, &signals, sched.as_mut(), 1);
        bench.record_value(
            "ablation: predictor live ttft",
            live.total.mean_ttft_s(),
            "s",
        );
        bench.record_value(
            "ablation: predictor live dropped",
            live.total.dropped,
            "req",
        );
    }

    // scenario sweep: optimizer quality + the stressed objective's best
    // value per named workload/grid regime (one mid-morning epoch each)
    for sc in Scenario::all() {
        let world = sc.build(&cfg, 8, 3);
        let (cp, dp) = build_panels(
            &world.cfg,
            &world.signals,
            4,
            &world.trace.epochs[4],
            world.cfg.physics.pr_off,
        );
        let sev = AnalyticEvaluator::new(
            cp,
            dp,
            EvalConsts::from_physics(&world.cfg.physics),
        );
        let mut opt_cfg = world.cfg.opt.clone();
        opt_cfg.generations = 6;
        opt_cfg.budget_s = 20.0;
        let mut o = SlitOptimizer::new(
            opt_cfg,
            world.cfg.num_classes(),
            sev.dcs(),
            9,
        );
        let out = o.optimize(&sev);
        let (_, hi) = out.archive.bounds();
        let mut reference = [0.0; N_OBJ];
        for i in 0..N_OBJ {
            reference[i] = hi[i] * 1.1 + 1e-9;
        }
        let hv =
            hypervolume(&out.archive.solutions, &reference, 20_000, 1);
        bench.record_value(
            &format!("scenario: {} hypervolume", sc.name()),
            hv,
            "hv",
        );
        let target = sc.target_objective();
        if let Some(best) = out.archive.best_for(target) {
            bench.record_value(
                &format!(
                    "scenario: {} best {}",
                    sc.name(),
                    OBJ_NAMES[target]
                ),
                best.obj[target],
                "obj",
            );
        }
        bench.record_value(
            &format!("scenario: {} true evals", sc.name()),
            out.evaluations as f64,
            "evals",
        );
        bench.record_value(
            &format!("scenario: {} memo hits", sc.name()),
            out.cache_hits as f64,
            "hits",
        );
    }

    bench.finish();
}
