//! Fig. 4 bench: the framework comparison (normalized TTFT / carbon /
//! cost / water vs Splitwise) at a reduced scale that keeps `cargo bench`
//! tractable, plus end-to-end simulation timing per framework.
//!
//! The canonical full-scale numbers live in EXPERIMENTS.md (from
//! examples/fig4_reproduction.rs); this bench tracks the same *shape*:
//! single-objective SLIT variants dominate their metric, SLIT-Balance
//! beats Helix everywhere.

use slit::config::{SystemConfig, N_OBJ, OBJ_NAMES};
use slit::power::GridSignals;
use slit::registry;
use slit::sim::simulate;
use slit::trace::Trace;
use slit::util::benchkit::Bench;

fn main() {
    let mut bench = Bench::new("fig4_frameworks").with_samples(5);

    // reduced scale: full topology, 1/10 nodes, 12 epochs
    let mut cfg = SystemConfig::paper_default();
    cfg.epochs = 12;
    cfg.opt.budget_s = 0.5;
    for d in &mut cfg.datacenters {
        d.nodes_per_type = d.nodes_per_type.iter().map(|&n| n / 10).collect();
    }
    cfg.workload.base_requests_per_epoch /= 10.0;

    let trace = Trace::generate(&cfg, cfg.epochs, cfg.seed);
    let signals = GridSignals::generate(&cfg, cfg.epochs, cfg.seed);

    let mut objs: Vec<(String, [f64; N_OBJ])> = Vec::new();
    for spec in registry::all().iter().filter(|f| f.in_paper_set) {
        let mut sched = (spec.build)(&cfg);
        let res = simulate(&cfg, &trace, &signals, sched.as_mut(), cfg.seed);
        objs.push((spec.name.to_string(), res.objectives()));
    }

    let base = objs
        .iter()
        .find(|(n, _)| n == "splitwise")
        .map(|(_, o)| *o)
        .unwrap();
    for (name, o) in &objs {
        for i in 0..N_OBJ {
            bench.record_value(
                &format!("fig4: {name} {} / splitwise", OBJ_NAMES[i]),
                o[i] / base[i].max(1e-12),
                "ratio",
            );
        }
    }

    // timing: one full simulate() per framework (decision + discrete exec)
    for name in ["helix", "splitwise", "slit-balance"] {
        bench.bench(&format!("simulate 12 epochs: {name}"), || {
            let mut sched =
                registry::build(name, &cfg, None).expect("scheduler");
            let r =
                simulate(&cfg, &trace, &signals, sched.as_mut(), cfg.seed);
            core::hint::black_box(r.total.requests);
        });
    }

    bench.finish();
}
