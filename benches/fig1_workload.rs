//! Fig. 1 bench: regenerate the two-week LLM token-request series and
//! verify/report its shape (small-model dominance, rapid intensity change,
//! bursts), plus trace-generation throughput.
//!
//! Run: `cargo bench --bench fig1_workload` (BENCH_QUICK=1 for CI speed).

use slit::config::{SystemConfig, MODELS};
use slit::trace::Trace;
use slit::util::benchkit::Bench;
use slit::util::stats;

fn main() {
    let mut bench = Bench::new("fig1_workload");
    let cfg = SystemConfig::paper_default();

    // --- the Fig. 1 series itself -----------------------------------------
    const TWO_WEEKS: usize = 14 * 96; // 1344 epochs of 15 min
    let trace = Trace::generate(&cfg, TWO_WEEKS, cfg.seed);
    let toks = trace.tokens_per_epoch();
    let mean = stats::mean(&toks);
    let (lo, hi) = stats::min_max(&toks);
    bench.record_value("fig1: epochs", TWO_WEEKS as f64, "epochs");
    bench.record_value("fig1: tokens/epoch mean", mean, "tokens");
    bench.record_value("fig1: tokens/epoch min", lo, "tokens");
    bench.record_value("fig1: tokens/epoch max (bursts)", hi, "tokens");
    bench.record_value("fig1: burst ratio max/mean", hi / mean, "x");

    // trend 1: small/old models dominate
    let mut small = 0.0;
    let mut large = 0.0;
    for e in &trace.epochs {
        for (k, c) in e.classes.iter().enumerate() {
            if k % MODELS == 0 {
                small += c.n_req;
            } else {
                large += c.n_req;
            }
        }
    }
    bench.record_value(
        "fig1: small-model request share",
        small / (small + large),
        "frac",
    );

    // trend 2: rapid epoch-to-epoch change
    let mut rel = Vec::new();
    for w in toks.windows(2) {
        if w[0] > 0.0 {
            rel.push(((w[1] - w[0]) / w[0]).abs());
        }
    }
    bench.record_value(
        "fig1: mean |epoch-to-epoch change|",
        stats::mean(&rel),
        "frac",
    );

    // --- generation cost ---------------------------------------------------
    bench.bench_throughput("generate 2-week trace", TWO_WEEKS as f64, "epoch", || {
        let t = Trace::generate(&cfg, TWO_WEEKS, 1);
        core::hint::black_box(t.epochs.len());
    });
    let mut rng = slit::util::rng::Rng::new(5);
    bench.bench("sample one epoch of requests", || {
        let reqs = trace.sample_requests(&cfg, 100, &mut rng);
        core::hint::black_box(reqs.len());
    });

    bench.finish();
}
