//! Fig. 5 bench: per-epoch time-domain comparison (Helix vs Splitwise vs
//! SLIT-Balance) at reduced scale — reports the per-epoch medians whose
//! full-scale counterparts are plotted in the paper's Fig. 5, plus the
//! per-epoch decision latency of each framework (the paper caps decision
//! time at one epoch = 15 min; ours is sub-second).

use slit::cli::make_scheduler;
use slit::config::SystemConfig;
use slit::power::GridSignals;
use slit::sim::simulate;
use slit::trace::Trace;
use slit::util::benchkit::Bench;
use slit::util::stats;

fn main() {
    let mut bench = Bench::new("fig5_time_domain").with_samples(5);

    let mut cfg = SystemConfig::paper_default();
    cfg.epochs = 16;
    cfg.opt.budget_s = 0.4;
    for d in &mut cfg.datacenters {
        d.nodes_per_type = d.nodes_per_type.iter().map(|&n| n / 10).collect();
    }
    cfg.workload.base_requests_per_epoch /= 10.0;

    let trace = Trace::generate(&cfg, cfg.epochs, cfg.seed);
    let signals = GridSignals::generate(&cfg, cfg.epochs, cfg.seed);

    for name in ["helix", "splitwise", "slit-balance"] {
        let mut sched = make_scheduler(name, &cfg, None).expect("scheduler");
        let res = simulate(&cfg, &trace, &signals, sched.as_mut(), cfg.seed);
        let series = |f: fn(&slit::models::EpochLedger) -> f64| -> Vec<f64> {
            res.per_epoch.iter().map(|e| f(&e.ledger)).collect()
        };
        bench.record_value(
            &format!("fig5: {name} ttft/epoch p50"),
            stats::percentile(&series(|l| l.mean_ttft_s()), 50.0),
            "s",
        );
        bench.record_value(
            &format!("fig5: {name} carbon/epoch p50"),
            stats::percentile(&series(|l| l.carbon_kg), 50.0),
            "kg",
        );
        bench.record_value(
            &format!("fig5: {name} water/epoch p50"),
            stats::percentile(&series(|l| l.water_l), 50.0),
            "L",
        );
        bench.record_value(
            &format!("fig5: {name} cost/epoch p50"),
            stats::percentile(&series(|l| l.cost_usd), 50.0),
            "$",
        );
        let decisions: Vec<f64> =
            res.per_epoch.iter().map(|e| e.decision_s).collect();
        bench.record_value(
            &format!("fig5: {name} decision time p95"),
            stats::percentile(&decisions, 95.0),
            "s",
        );
    }

    bench.finish();
}
